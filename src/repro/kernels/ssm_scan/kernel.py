"""Mamba selective-scan kernel (TPU Pallas).

Recurrence per channel d with state h: (N,):

    h_t = exp(dt_t * A_d) * h_{t-1} + dt_t * B_t * u_t
    y_t = C_t . h_t

Tiling: grid = (B, n_d_blocks, T // block_t) with time grid-minor so the
(block_d, N) state persists in VMEM scratch across time blocks.  u/dt tiles
are (block_t, block_d); B/C tiles (block_t, N) are shared across the channel
block.  A is (block_d, N), loaded per channel block.  D and the skip path
are applied by the wrapper (elementwise, fusible by XLA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *,
                block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    u = u_ref[0].astype(jnp.float32)      # (bt, bd)
    dt = dt_ref[0].astype(jnp.float32)    # (bt, bd)
    a = a_ref[...].astype(jnp.float32)    # (bd, N)
    bm = b_ref[0].astype(jnp.float32)     # (bt, N)
    cm = c_ref[0].astype(jnp.float32)     # (bt, N)

    def step(t, carry):
        h, ys = carry                      # h: (bd, N)
        dA = jnp.exp(dt[t][:, None] * a)   # (bd, N)
        dBu = dt[t][:, None] * bm[t][None, :] * u[t][:, None]
        h = dA * h + dBu
        y = (h * cm[t][None, :]).sum(axis=1)          # (bd,)
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, t, axis=0)
        return h, ys

    ys0 = jnp.zeros((block_t, u.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, block_t, step, (h_scr[...], ys0))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def ssm_scan_fwd(u, dt, a, b, c, *, block_t: int = 64, block_d: int = 128,
                 interpret: bool = False):
    """u, dt: (B, T, D); a: (D, N); b, c: (B, T, N). Returns y: (B, T, D)."""
    bsz, t, d = u.shape
    n = a.shape[1]
    block_t = min(block_t, t)
    block_d = min(block_d, d)
    assert t % block_t == 0 and d % block_d == 0, (t, block_t, d, block_d)
    n_t, n_d = t // block_t, d // block_d

    kernel = functools.partial(_ssm_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=(bsz, n_d, n_t),
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda b_, i, j: (b_, j, i)),
            pl.BlockSpec((1, block_t, block_d), lambda b_, i, j: (b_, j, i)),
            pl.BlockSpec((block_d, n), lambda b_, i, j: (i, 0)),
            pl.BlockSpec((1, block_t, n), lambda b_, i, j: (b_, j, 0)),
            pl.BlockSpec((1, block_t, n), lambda b_, i, j: (b_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_d),
                               lambda b_, i, j: (b_, j, i)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, d), u.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, a, b, c)
