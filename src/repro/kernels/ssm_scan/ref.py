"""Pure-jnp oracle for ssm_scan (mirrors models.ssm._selective_scan core)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(u, dt, a, b, c):
    """u, dt: (B, T, D); a: (D, N); b, c: (B, T, N). Returns y: (B, T, D)."""
    bsz, t, d = u.shape
    n = a.shape[1]

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[..., None] * a[None])
        dBu = dt_t[..., None] * b_t[:, None, :] * u_t[..., None]
        h = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = tuple(x.transpose(1, 0, 2).astype(jnp.float32) for x in (u, dt, b, c))
    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(u.dtype)
