"""Public ppa_eval op: decode indices -> kernel -> metrics dict."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ppa_eval.kernel import ppa_eval_fwd
from repro.kernels.ppa_eval.ref import op_table
from repro.perfmodel.designspace import DesignSpace, SPACE
from repro.perfmodel.workload import Workload


def ppa_eval(idx: np.ndarray, wl: Workload, space: DesignSpace = SPACE, *,
             block_b: int = 256, interpret: bool = None) -> dict:
    """Evaluate a batch of design-index vectors with the Pallas kernel.

    Returns {"latency": (B,), "stall": (B,4), "area": (B,)}.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    idx = np.atleast_2d(np.asarray(idx, dtype=np.int32))
    b = idx.shape[0]
    pad = (-b) % block_b if b > block_b else (block_b - b if b < block_b else 0)
    if pad:
        idx = np.concatenate([idx, np.repeat(idx[-1:], pad, axis=0)], axis=0)
    vals = space.decode(jnp.asarray(idx))
    dv = jnp.stack([vals[n] for n in space.names], axis=1).astype(jnp.float32)
    tab = jnp.asarray(op_table(wl), jnp.float32)
    out = ppa_eval_fwd(dv, tab, tp=float(wl.tp),
                       block_b=min(block_b, dv.shape[0]), interpret=interpret)
    out = np.asarray(out)[:b]
    return {"latency": out[:, 0], "stall": out[:, 1:5], "area": out[:, 5]}
