"""Batched design-point PPA evaluation kernel (TPU Pallas).

The Lumina substrate hot loop: evaluate a block of candidate architectures
against a workload operator table (roofline tier).  This is the computation
the paper reports costing 6000 CPU-hours per 1000 LLMCompass samples; the
vectorized JAX model brings it to seconds, and this kernel is the TPU-native
tiling of that evaluation for full-space (4.7M-point) sweeps.

Tiling: grid = (n_design_blocks,); each step loads a (block_b, 8) tile of
decoded design values into VMEM plus the whole (n_ops, 8) operator table
(tiny — every workload here is < 128 ops), and runs a fori_loop over ops
accumulating latency and the four per-stall-class times entirely in
registers/VMEM.  Output tile: (block_b, 8) = [latency, 4 stalls, area, 0, 0].

Math mirrors repro.perfmodel.roofline exactly (ref.py delegates to it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.perfmodel.hardware import (
    AREA_BASE, AREA_CORE_BASE, AREA_PER_CHANNEL, AREA_PER_GBUF_MB,
    AREA_PER_LINK, AREA_PER_MAC, AREA_PER_SRAM_KB, AREA_PER_VLANE,
    BW_PER_CHANNEL, BW_PER_LINK, BYTES_FP16, CLOCK_HZ, LINK_LATENCY_S)
from repro.perfmodel.roofline import SRAM_FEED_WORDS_PER_KB
from repro.perfmodel import workload as W

# design-value column order (matches designspace.PARAM_NAMES)
LINKS, CORES, SUBLANES, SA, VW, SRAM, GBUF, CHAN = range(8)
# op-table column order
OP_KIND, OP_FLOPS, OP_BYTES, OP_M, OP_N, OP_K, OP_COMM, OP_COUNT = range(8)


def _ceil_div(a, b):
    return jnp.ceil(a / b)


def _ppa_kernel(dv_ref, ops_ref, out_ref, *, n_ops: int, tp: float):
    dv = dv_ref[...].astype(jnp.float32)          # (bb, 8)
    ops = ops_ref[...].astype(jnp.float32)        # (n_ops, 8)

    cores, sub, sa, vw = dv[:, CORES], dv[:, SUBLANES], dv[:, SA], dv[:, VW]
    sram, gbuf_mb, chan, links = dv[:, SRAM], dv[:, GBUF], dv[:, CHAN], dv[:, LINKS]

    tensor = cores * sub * sa * sa * 2.0 * CLOCK_HZ
    vector = cores * sub * vw * 2.0 * CLOCK_HZ
    mem_bw = chan * BW_PER_CHANNEL
    ici_bw = links * BW_PER_LINK
    gbuf_elems = jnp.maximum(gbuf_mb * 2.0 ** 20 / BYTES_FP16, 1.0)

    bb = dv.shape[0]
    lat0 = jnp.zeros((bb,), jnp.float32)
    stalls0 = jnp.zeros((bb, 4), jnp.float32)

    def body(i, carry):
        lat, stalls = carry
        kind = ops[i, OP_KIND]
        flops, nbytes = ops[i, OP_FLOPS], ops[i, OP_BYTES]
        m, n, k = ops[i, OP_M], ops[i, OP_N], ops[i, OP_K]
        comm, count = ops[i, OP_COMM], ops[i, OP_COUNT]

        # matmul utilization (mirrors roofline.matmul_utilization)
        u_k = k / (_ceil_div(k, sa) * sa)
        u_n = n / (_ceil_div(n, sa) * sa)
        u_pipe = m / (m + sa)
        n_tiles = _ceil_div(m, sa) * _ceil_div(n, sa)
        u_par = jnp.minimum(1.0, n_tiles / (cores * sub))
        sram_need = 3.0 * 2.0 * sa * sa * BYTES_FP16 / 1024.0
        u_sram = jnp.minimum(1.0, sram / sram_need)
        u_feed = jnp.minimum(1.0, SRAM_FEED_WORDS_PER_KB * sram / (sa * sub))
        util = u_k * u_n * u_pipe * u_par * u_sram * u_feed

        is_mm = kind == W.MATMUL
        is_vec = kind == W.VECTOR
        is_ar = kind == W.ALLREDUCE
        is_p2p = kind == W.P2P

        bytes_eff = jnp.where(
            is_mm,
            jnp.maximum(nbytes, 2.0 * m * n * k / jnp.sqrt(gbuf_elems) * BYTES_FP16),
            nbytes)
        t_c = jnp.where(is_mm, flops / (tensor * util),
                        jnp.where(is_vec, flops / vector, 0.0))
        t_m = bytes_eff / mem_bw
        steps_ar = 2.0 * (tp - 1.0)
        t_ar = steps_ar / tp * comm / ici_bw + steps_ar * LINK_LATENCY_S
        t_p2p = (tp - 1.0) / tp * comm / ici_bw + (tp - 1.0) * LINK_LATENCY_S
        t_x = jnp.where(is_ar, t_ar, jnp.where(is_p2p, t_p2p, 0.0))

        t_op = jnp.maximum(jnp.maximum(t_c, t_m), t_x) * count
        dom_comm = (t_x >= t_c) & (t_x >= t_m)
        dom_compute = (t_c > t_m) & ~dom_comm
        cls = jnp.where(dom_comm, 3,
                        jnp.where(dom_compute, jnp.where(is_mm, 0, 1), 2))
        onehot = (cls[:, None] == jnp.arange(4)[None, :]).astype(jnp.float32)
        return lat + t_op, stalls + onehot * t_op[:, None]

    lat, stalls = jax.lax.fori_loop(0, n_ops, body, (lat0, stalls0))

    macs = sub * sa * sa
    core_area = (AREA_CORE_BASE + AREA_PER_MAC * macs + AREA_PER_VLANE * sub * vw
                 + AREA_PER_SRAM_KB * sram)
    area = (AREA_BASE + cores * core_area + AREA_PER_GBUF_MB * gbuf_mb
            + AREA_PER_CHANNEL * chan + AREA_PER_LINK * links)

    out = jnp.concatenate(
        [lat[:, None], stalls, area[:, None],
         jnp.zeros((bb, 2), jnp.float32)], axis=1)
    out_ref[...] = out


def ppa_eval_fwd(design_values: jnp.ndarray, op_table: jnp.ndarray, *,
                 tp: float = 8.0, block_b: int = 256,
                 interpret: bool = False) -> jnp.ndarray:
    """design_values: (B, 8) decoded physical values (PARAM_NAMES order);
    op_table: (n_ops, 8).  Returns (B, 8): [latency, s0..s3, area, 0, 0]."""
    b = design_values.shape[0]
    block_b = min(block_b, b)
    assert b % block_b == 0, (b, block_b)
    n_ops = op_table.shape[0]
    kernel = functools.partial(_ppa_kernel, n_ops=n_ops, tp=tp)
    return pl.pallas_call(
        kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, 8), lambda i: (i, 0)),
            pl.BlockSpec((n_ops, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 8), jnp.float32),
        interpret=interpret,
    )(design_values, op_table)
