"""Oracle for ppa_eval: the vectorized RooflineModel itself."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.perfmodel.roofline import RooflineModel
from repro.perfmodel.designspace import DesignSpace, SPACE
from repro.perfmodel.workload import Workload


def op_table(wl: Workload) -> np.ndarray:
    """Workload -> (n_ops, 8) float table in the kernel's column order."""
    a = wl.arrays()
    return np.stack([
        a["kind"].astype(np.float64), a["flops"], a["bytes"],
        a["m"], a["n"], a["k"], a["comm_bytes"], a["count"],
    ], axis=1)


def ppa_eval_ref(idx: np.ndarray, wl: Workload,
                 space: DesignSpace = SPACE) -> np.ndarray:
    """idx: (B, n_params) choice indices. Returns (B, 8) like the kernel."""
    from repro.perfmodel.evaluator import evaluator_for_model
    rep = evaluator_for_model(RooflineModel(wl, space)).stalls(idx)
    w = rep.workloads[0]
    b = rep.n
    return np.concatenate([
        rep.latency[w][:, None], rep.stall[w], rep.area[:, None],
        np.zeros((b, 2)),
    ], axis=1).astype(np.float32)
