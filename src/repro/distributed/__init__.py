"""Distributed evaluation service layer.

Three composable pieces turn the single-process evaluator into an
always-on service that can absorb heavy concurrent DSE traffic AND
survive worker failure:

* :class:`~repro.distributed.sharded.ShardedEvaluator` — fans ONE
  :class:`~repro.perfmodel.evaluator.EvalRequest`'s design batch across N
  workers (in-process threads, spawned processes, or per-device pins) and
  reassembles a single bit-identical
  :class:`~repro.perfmodel.evaluator.PPAReport`, with per-shard retry
  (jittered-backoff :class:`~repro.runtime.fault.RetryPolicy`), shard
  timeouts, receiver-side payload validation, straggler re-dispatch,
  heartbeat-tracked worker liveness and elastic pool resize.
  ``get_evaluator(..., workers=N)`` wraps the paper evaluators in one.
* :class:`~repro.distributed.service.EvalService` — an async request
  queue whose coalescing batcher merges concurrent requests from ANY
  number of clients (K campaigns, baselines, benches) into one fused
  dispatch per tick, resolved via futures and a shared cross-client
  report cache.  On worker loss or deadline pressure a request DEGRADES
  along a declared ladder (narrow the pool -> objectives proxy -> cached
  rows) instead of failing.
* :mod:`~repro.distributed.faults` — the chaos harness proving the
  above: a seeded deterministic :class:`~repro.distributed.faults.
  FaultPlan` of crash/hang/slow/corrupt events, a
  :class:`~repro.distributed.faults.ChaosPool` wrapper composing with
  every pool, and the :class:`~repro.distributed.faults.WorkerRegistry`
  liveness tracker.

The pieces compose: ``EvalService(ShardedEvaluator(base, workers=N,
fault_plan=plan))`` coalesces across clients, shards across workers and
injects failures deterministically.  The multi-worker full-space sweep
lives with its engine: ``SweepEngine(...).run(workers=N,
fault_plan=plan)``.

Cross-machine, the same pieces ride TCP: :mod:`repro.serve` adds the
socket worker fabric (``mode='socket'`` + ``addresses=``), QoS tiers in
the service tick (``submit(..., tier=...)``) and the admission-controlled
:class:`~repro.serve.gateway.Gateway` front door.
"""

from repro.distributed.faults import (FAULT_KINDS, ChaosPool, FaultEvent,
                                      FaultPlan, WorkerFault, WorkerRegistry)
from repro.distributed.service import (DEGRADE_RUNGS, QOS_TIERS, EvalService)
from repro.distributed.sharded import (MODES, ShardedEvaluator, ShardPayload,
                                       concat_reports, evaluator_from_spec)

__all__ = ["EvalService", "ShardedEvaluator", "ShardPayload",
           "concat_reports", "evaluator_from_spec", "MODES",
           "DEGRADE_RUNGS", "QOS_TIERS",
           "FaultPlan", "FaultEvent", "ChaosPool", "WorkerFault",
           "WorkerRegistry", "FAULT_KINDS"]
