"""Distributed evaluation service layer.

Two composable pieces turn the single-process evaluator into a service
that can absorb heavy concurrent DSE traffic:

* :class:`~repro.distributed.sharded.ShardedEvaluator` — fans ONE
  :class:`~repro.perfmodel.evaluator.EvalRequest`'s design batch across N
  workers (in-process threads, spawned processes, or per-device pins) and
  reassembles a single bit-identical
  :class:`~repro.perfmodel.evaluator.PPAReport`, with per-shard retry and
  straggler re-dispatch.  ``get_evaluator(..., workers=N)`` wraps the
  paper evaluators in one.
* :class:`~repro.distributed.service.EvalService` — an async request
  queue whose coalescing batcher merges concurrent requests from ANY
  number of clients (K campaigns, baselines, benches) into one fused
  dispatch per tick, resolved via futures and a shared cross-client
  report cache.

The two compose: ``EvalService(ShardedEvaluator(base, workers=N))``
coalesces across clients and shards across workers.  The multi-worker
full-space sweep lives with its engine:
``SweepEngine(...).run(workers=N)``.
"""

from repro.distributed.service import EvalService
from repro.distributed.sharded import (MODES, ShardedEvaluator, ShardPayload,
                                       concat_reports)

__all__ = ["EvalService", "ShardedEvaluator", "ShardPayload",
           "concat_reports", "MODES"]
