"""Deterministic chaos injection + worker liveness for the eval service.

The always-on DSE service must survive worker crashes, hangs, slowdowns
and corrupted payloads.  This module supplies both halves of proving
that:

* **Injection** — :class:`FaultPlan` is a seeded, fully deterministic
  schedule of fault events keyed by ``(worker, dispatch)``;
  :class:`ChaosPool` wraps ANY worker pool
  (``inline | thread | process | device``) and applies the plan's events
  to the pool's dispatch stream WITHOUT real process kills, so
  :class:`~repro.distributed.sharded.ShardedEvaluator`,
  :class:`~repro.distributed.service.EvalService` and
  :class:`~repro.perfmodel.sweep.SweepEngine` can be exercised under
  failure in unit tests and CI.  Events are consumed exactly once
  (:meth:`FaultPlan.fire`), so a retried dispatch lands on a clean slot
  and recovery converges.
* **Liveness** — :class:`WorkerRegistry` tracks per-worker heartbeats
  with the same expiry semantics as the file-based
  :class:`~repro.runtime.fault.Heartbeat` (beat / timeout / evict /
  re-register), in process.  :class:`~repro.distributed.sharded.
  ShardedEvaluator` beats it on shard completion, evicts workers whose
  dispatches crash or time out, and re-registers replacements when the
  pool resizes (:func:`~repro.runtime.elastic.plan_elastic_pool` decides
  the size).

Fault kinds
-----------
``crash``    the dispatch fails immediately (``WorkerFault``);
``hang``     the dispatch never completes (exercises shard timeouts and
             straggler speculation);
``slow``     the result is delayed by ``delay_s`` (exercises straggler
             detection without data loss);
``corrupt``  the result's payload is corrupted (non-finite / negated
             values — exercises the receiver-side integrity check).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

FAULT_KINDS = ("crash", "hang", "slow", "corrupt")


class WorkerFault(RuntimeError):
    """An injected (or detected) worker failure — retryable by policy."""


class QuotaExceeded(WorkerFault):
    """A worker REFUSED a dispatch by policy (``quota.rows`` /
    ``quota.rate`` / ``quota.concurrency`` / ``quota.deadline``): the
    worker is healthy and the shard is fine — it just will not run HERE
    right now.  :class:`~repro.distributed.sharded.ShardedEvaluator`
    treats it as non-retryable-at-this-worker: reroute to another slot
    without consuming retry budget, without backoff, and without
    evicting the refusing worker."""

    def __init__(self, message: str, code: str = "quota"):
        super().__init__(message)
        self.code = code


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: hits dispatch number `dispatch` attributed to
    worker slot `worker` (slots are assigned round-robin by dispatch
    order, the same attribution the pools use)."""
    worker: int
    dispatch: int
    kind: str
    delay_s: float = 0.05          # slow-fault delay

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"kind must be one of {FAULT_KINDS}, "
                             f"got {self.kind!r}")


class FaultPlan:
    """A deterministic, seeded schedule of fault events.

    Events are keyed by ``(worker, dispatch)`` and CONSUMED on fire: a
    retry of a crashed dispatch gets a fresh ordinal, so the same event
    can never re-kill its own recovery.  Thread-safe (pools fire from
    worker threads).
    """

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self._lock = threading.Lock()
        self._events: Dict[Tuple[int, int], FaultEvent] = {}
        for e in events:
            self._events[(e.worker, e.dispatch)] = e
        self.scheduled = len(self._events)
        self.fired: Dict[str, int] = {k: 0 for k in FAULT_KINDS}

    @classmethod
    def seeded(cls, seed: int, *, workers: int, dispatches: int,
               rate: float = 0.2,
               kinds: Tuple[str, ...] = FAULT_KINDS,
               delay_s: float = 0.05) -> "FaultPlan":
        """A reproducible random plan: each of the first `dispatches`
        dispatch ordinals faults with probability `rate`, cycling worker
        attribution round-robin.  Same seed -> same schedule, always."""
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for d in range(dispatches):
            if rng.random() < rate:
                events.append(FaultEvent(
                    worker=d % max(1, workers), dispatch=d,
                    kind=kinds[int(rng.integers(len(kinds)))],
                    delay_s=delay_s))
        return cls(events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def peek(self, worker: int, dispatch: int) -> Optional[FaultEvent]:
        with self._lock:
            return self._events.get((worker, dispatch))

    def fire(self, worker: int, dispatch: int) -> Optional[FaultEvent]:
        """The event scheduled for this (worker, dispatch), consumed."""
        with self._lock:
            ev = self._events.pop((worker, dispatch), None)
            if ev is not None:
                self.fired[ev.kind] += 1
            return ev


def corrupt_report(rep):
    """Corrupt a PPAReport payload the way a flaky wire would: negate the
    area and poison the first latency entry of every workload with NaN.
    The receiver-side integrity check must reject exactly this."""
    import copy
    bad = copy.copy(rep)
    bad.area = -np.asarray(rep.area)
    bad.latency = {nm: v.copy() for nm, v in rep.latency.items()}
    for nm in bad.latency:
        if bad.latency[nm].size:
            bad.latency[nm][0] = np.nan
    return bad


class ChaosPool:
    """Fault-injecting wrapper composing with every worker pool.

    Keeps its own dispatch counter; each submitted payload is attributed
    to worker slot ``dispatch % workers`` (deterministic round-robin — the
    same attribution :class:`~repro.distributed.sharded.ShardedEvaluator`
    uses for liveness bookkeeping) and checked against the plan:

    * ``crash``   -> an already-failed future (``WorkerFault``);
    * ``hang``    -> a future that never resolves;
    * ``slow``    -> the real result, delivered after ``delay_s``;
    * ``corrupt`` -> the real result with a corrupted payload.

    ``injected`` counts applied events by kind.
    """

    def __init__(self, inner, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self.mode = inner.mode
        self.dispatch_count = 0
        self.injected: Dict[str, int] = {k: 0 for k in FAULT_KINDS}
        self._lock = threading.Lock()

    @property
    def workers(self) -> int:
        return self.inner.workers

    def submit(self, payload) -> Future:
        with self._lock:
            d = self.dispatch_count
            self.dispatch_count += 1
        ev = self.plan.fire(d % max(1, self.workers), d)
        if ev is None:
            return self.inner.submit(payload)
        self.injected[ev.kind] += 1
        if ev.kind == "crash":
            fut: Future = Future()
            fut.set_exception(WorkerFault(
                f"injected crash: worker {ev.worker} dispatch {d}"))
            return fut
        if ev.kind == "hang":
            return Future()                      # pending forever
        inner_fut = self.inner.submit(payload)
        out: Future = Future()

        def _copy(f: Future) -> None:
            if out.cancelled() or out.done():
                return                       # receiver already abandoned us
            try:
                try:
                    res = f.result()
                except BaseException as exc:
                    out.set_exception(exc)
                    return
                out.set_result(corrupt_report(res) if ev.kind == "corrupt"
                               else res)
            except Exception:                # cancelled between check and set
                pass

        if ev.kind == "slow":
            def _delayed(f: Future) -> None:
                t = threading.Timer(ev.delay_s, _copy, args=(f,))
                t.daemon = True
                t.start()
            inner_fut.add_done_callback(_delayed)
        else:
            inner_fut.add_done_callback(_copy)
        return out

    def resize(self, workers: int) -> None:
        self.inner.resize(workers)

    def close(self) -> None:
        self.inner.close()


class WorkerRegistry:
    """In-process worker liveness: heartbeats, eviction, re-registration.

    The in-memory sibling of the file-based :class:`~repro.runtime.fault.
    Heartbeat` watchdog: a worker is alive while its last beat is younger
    than ``timeout_s``.  ``evict_dead()`` removes expired workers (and
    anything explicitly :meth:`mark_dead`-ed); a later :meth:`register`
    of the same id is a RE-registration (the worker came back or was
    replaced) and counts as one.  ``now`` is injectable for tests.
    """

    def __init__(self, timeout_s: float = 30.0, now=time.monotonic):
        self.timeout_s = float(timeout_s)
        self._now = now
        self._lock = threading.Lock()
        self._beats: Dict[int, float] = {}
        self._dead: set = set()
        self._known: set = set()
        self.evictions = 0
        self.reregistrations = 0

    def register(self, worker: int) -> None:
        with self._lock:
            if worker in self._known and worker not in self._beats:
                self.reregistrations += 1
            self._known.add(worker)
            self._dead.discard(worker)
            self._beats[worker] = self._now()

    def beat(self, worker: int) -> None:
        with self._lock:
            if worker in self._beats:
                self._beats[worker] = self._now()

    def mark_dead(self, worker: int) -> None:
        """Flag a worker for eviction regardless of its heartbeat age
        (crash / timeout attribution beats the passive expiry clock)."""
        with self._lock:
            if worker in self._beats:
                self._dead.add(worker)

    def alive(self, worker: int) -> bool:
        with self._lock:
            ts = self._beats.get(worker)
            return (ts is not None and worker not in self._dead
                    and self._now() - ts < self.timeout_s)

    def live(self) -> List[int]:
        now = self._now()
        with self._lock:
            return sorted(w for w, ts in self._beats.items()
                          if w not in self._dead
                          and now - ts < self.timeout_s)

    def evict_dead(self) -> List[int]:
        """Remove expired / flagged workers; returns the evicted ids."""
        now = self._now()
        with self._lock:
            gone = sorted(w for w, ts in self._beats.items()
                          if w in self._dead or now - ts >= self.timeout_s)
            for w in gone:
                del self._beats[w]
                self._dead.discard(w)
            self.evictions += len(gone)
            return gone

    def snapshot(self) -> Dict[str, object]:
        """Fleet state for telemetry: live/known ids, beat ages, and the
        eviction / re-registration counters (gateway `telemetry()` rides
        this)."""
        now = self._now()
        with self._lock:
            live = sorted(w for w, ts in self._beats.items()
                          if w not in self._dead
                          and now - ts < self.timeout_s)
            return {
                "live": live,
                "known": sorted(self._known),
                "beat_age_s": {w: round(now - ts, 3)
                               for w, ts in sorted(self._beats.items())},
                "timeout_s": self.timeout_s,
                "evictions": self.evictions,
                "reregistrations": self.reregistrations,
            }

    def __len__(self) -> int:
        with self._lock:
            return len(self._beats)
