"""Sharded multi-worker evaluation behind the Evaluator protocol.

:class:`ShardedEvaluator` splits one :class:`~repro.perfmodel.evaluator.
EvalRequest`'s design batch into N contiguous shards, dispatches them to a
worker pool, and reassembles a single :class:`~repro.perfmodel.evaluator.
PPAReport` **bit-identical** to the local :class:`~repro.perfmodel.
evaluator.ModelEvaluator` on the same request (every per-design value is
row-wise, so shard boundaries never change a float).

Worker pools
------------
``inline``   — the ``workers=1`` in-process fallback: evaluate on the
               caller's thread (zero overhead, always available).
``thread``   — a thread pool over ONE process-local evaluator; jitted
               executables are shared, shards overlap host pre/post work.
               The default for ``workers > 1``.
``process``  — spawned worker processes, each constructing its own
               evaluator from a pickled (model class, workload, space)
               spec — the multi-host template: nothing is shared but the
               request/report wire format.
``device``   — thread pool that pins shard k to ``jax.devices()[k % D]``
               (round-robin), for hosts with more than one accelerator.
``socket``   — remote ``repro.serve`` workers over TCP (``addresses=``);
               the cross-machine realization of the ``process`` template:
               the same pickled spec rides a :class:`~repro.serve.wire.
               Hello` handshake and the same ``ShardPayload`` ->
               ``PPAReport`` exchange rides length-prefixed frames.

Fault handling
--------------
A shard that raises — or whose report fails the receiver-side integrity
check (shape mismatch, non-finite or non-positive values: the
corrupt-payload guard) — is retried on a fresh worker under a
:class:`~repro.runtime.fault.RetryPolicy` (budget + jittered exponential
backoff); a shard still pending past ``shard_timeout_s`` is declared
lost, its worker slot is evicted from the :class:`~repro.distributed.
faults.WorkerRegistry` and a replacement re-registers (``elastic=True``
additionally resizes the pool via :func:`~repro.runtime.elastic.
plan_elastic_pool`).  A straggler — a shard still pending after
``straggler_factor`` x the median completed-shard time — is speculatively
re-dispatched and whichever twin finishes first wins (results are
identical by construction, so the race is benign).  ``worker_dispatches``
/ ``retried`` / ``timeouts`` / ``corrupt_rejected`` /
``straggler_redispatches`` / ``resizes`` count the traffic.  A seeded
:class:`~repro.distributed.faults.FaultPlan` (``fault_plan=``) wraps the
pool in a :class:`~repro.distributed.faults.ChaosPool` for deterministic
failure injection without real process kills.
"""
from __future__ import annotations

import itertools
import math
import pickle
import time
from concurrent.futures import (FIRST_COMPLETED, Future, ProcessPoolExecutor,
                                ThreadPoolExecutor, wait)
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.distributed.faults import (ChaosPool, FaultPlan, QuotaExceeded,
                                      WorkerFault, WorkerRegistry)
from repro.obs.metrics import Clock, MetricsRegistry
from repro.obs.trace import NOOP
from repro.perfmodel.evaluator import (EvalRequest, ModelEvaluator, PPAReport,
                                       as_evaluator)
from repro.runtime.elastic import plan_elastic_pool
from repro.runtime.fault import RetryPolicy

MODES = ("auto", "inline", "thread", "process", "device", "socket")


@dataclass(frozen=True)
class ShardPayload:
    """One shard of an EvalRequest on the worker wire format."""
    idx: np.ndarray
    detail: str
    workloads: Optional[Tuple[str, ...]]


def _eval_payload(evaluator, payload: ShardPayload) -> PPAReport:
    return evaluator.evaluate(EvalRequest(payload.idx, payload.detail,
                                          payload.workloads))


def concat_reports(parts: List[PPAReport]) -> PPAReport:
    """Reassemble shard reports into one batch report (shard order)."""
    first = parts[0]
    if len(parts) == 1:
        return first
    names = first.workloads

    def cat(field):
        return {nm: np.concatenate([getattr(p, field)[nm] for p in parts])
                for nm in names}

    rep = PPAReport(workloads=names, detail=first.detail,
                    area=np.concatenate([p.area for p in parts]),
                    latency=cat("latency"))
    if first.op_time is not None:
        rep.op_time = cat("op_time")
        rep.op_names = first.op_names
    if first.stall is not None:
        rep.stall = cat("stall")
        rep.op_class = cat("op_class")
    return rep


# ---------------------------------------------------------------------------
# worker pools
# ---------------------------------------------------------------------------

class _InlinePool:
    """workers=1 fallback: evaluate on the caller's thread."""
    mode = "inline"

    def __init__(self, base, workers: int = 1):
        self._base = base
        self.workers = 1

    def submit(self, payload: ShardPayload) -> Future:
        fut: Future = Future()
        try:
            fut.set_result(_eval_payload(self._base, payload))
        except BaseException as exc:            # surfaced via fut.result()
            fut.set_exception(exc)
        return fut

    def resize(self, workers: int) -> None:
        pass                                   # always exactly one worker

    def close(self) -> None:
        pass


class _ThreadPool:
    """Thread workers over one shared process-local evaluator."""
    mode = "thread"

    def __init__(self, base, workers: int):
        self._base = base
        self.workers = int(workers)
        self._ex = ThreadPoolExecutor(max_workers=self.workers,
                                      thread_name_prefix="shard-eval")

    def submit(self, payload: ShardPayload) -> Future:
        return self._ex.submit(_eval_payload, self._base, payload)

    def resize(self, workers: int) -> None:
        """Swap in a fresh executor of the new size; in-flight tasks on the
        old one run to completion (their futures stay valid)."""
        workers = max(1, int(workers))
        if workers == self.workers:
            return
        old = self._ex
        self.workers = workers
        self._ex = ThreadPoolExecutor(max_workers=workers,
                                      thread_name_prefix="shard-eval")
        old.shutdown(wait=False)

    def close(self) -> None:
        self._ex.shutdown(wait=False, cancel_futures=True)


class _DevicePool(_ThreadPool):
    """Thread workers, shard k pinned to jax device k % D (round-robin)."""
    mode = "device"

    def __init__(self, base, workers: int):
        super().__init__(base, workers)
        import jax
        devs = jax.devices()
        self._devices = [devs[i % len(devs)] for i in range(self.workers)]
        self._rr = itertools.count()

    def resize(self, workers: int) -> None:
        super().resize(workers)
        import jax
        devs = jax.devices()
        self._devices = [devs[i % len(devs)] for i in range(self.workers)]

    def submit(self, payload: ShardPayload) -> Future:
        import jax
        dev = self._devices[next(self._rr) % self.workers]

        def task():
            with jax.default_device(dev):
                return _eval_payload(self._base, payload)

        return self._ex.submit(task)


# -- process pool: workers rebuild the evaluator from a pickled spec --------

_WORKER_EVALUATOR: Optional[ModelEvaluator] = None


def _worker_spec(base: ModelEvaluator) -> bytes:
    """(model class, workload, space, tier, backend) — everything a spawned
    worker needs to reconstruct an equivalent evaluator from scratch.

    These bytes are a cross-machine wire format (`repro.serve` workers
    rebuild from the very same spec), so they are pinned to
    ``pickle.HIGHEST_PROTOCOL`` and covered by a round-trip regression
    test — change the layout and :func:`evaluator_from_spec` together.
    """
    return pickle.dumps({
        "models": {nm: (type(m), m.wl) for nm, m in base.models.items()},
        "space": base.space,
        "tier": base.tier,
        "backend": base.backend,
        "scenarios": getattr(base, "scenarios", None),
        "stacked": getattr(base, "stacked", None),
    }, protocol=pickle.HIGHEST_PROTOCOL)


def evaluator_from_spec(spec_bytes: bytes, loads=None) -> ModelEvaluator:
    """Rebuild the evaluator a :func:`_worker_spec` blob describes — the
    worker half of the wire contract, shared by the process pool
    initializer and the ``repro.serve`` socket daemon.

    ``loads`` overrides the deserializer: hardened workers pass
    :func:`repro.serve.codec.restricted_loads` so spec bytes resolve only
    allowlisted constructors; the default raw ``pickle.loads`` is the
    single-trust-domain process-pool path (lint-baselined under the
    ``pickle-outside-codec`` rule).
    """
    spec = pickle.loads(spec_bytes) if loads is None else loads(spec_bytes)
    models = {nm: cls(wl, spec["space"])
              for nm, (cls, wl) in spec["models"].items()}
    return ModelEvaluator(models, tier=spec["tier"],
                          backend=spec["backend"],
                          scenarios=spec.get("scenarios"),
                          stacked=spec.get("stacked"))


def _process_init(spec_bytes: bytes) -> None:
    global _WORKER_EVALUATOR
    _WORKER_EVALUATOR = evaluator_from_spec(spec_bytes)


def _process_eval(payload: ShardPayload) -> PPAReport:
    return _eval_payload(_WORKER_EVALUATOR, payload)


class _ProcessPool:
    """Spawned local processes — the multi-host sharding template."""
    mode = "process"

    def __init__(self, base, workers: int):
        if not isinstance(base, ModelEvaluator):
            raise TypeError("mode='process' needs a ModelEvaluator base "
                            "(workers rebuild it from its models)")
        import multiprocessing as mp
        self.workers = int(workers)
        self._spec = _worker_spec(base)
        self._mp_context = mp.get_context("spawn")
        self._ex = self._make_executor()

    def _make_executor(self) -> ProcessPoolExecutor:
        return ProcessPoolExecutor(
            max_workers=self.workers,
            mp_context=self._mp_context,
            initializer=_process_init, initargs=(self._spec,))

    def submit(self, payload: ShardPayload) -> Future:
        return self._ex.submit(_process_eval, payload)

    def resize(self, workers: int) -> None:
        workers = max(1, int(workers))
        if workers == self.workers:
            return
        old = self._ex
        self.workers = workers
        self._ex = self._make_executor()
        old.shutdown(wait=False)

    def close(self) -> None:
        self._ex.shutdown(wait=False, cancel_futures=True)


_POOLS = {"inline": _InlinePool, "thread": _ThreadPool,
          "process": _ProcessPool, "device": _DevicePool}


# ---------------------------------------------------------------------------
# the sharded evaluator
# ---------------------------------------------------------------------------

class ShardedEvaluator:
    """Fan one EvalRequest across N workers; gather one PPAReport.

    Implements the :class:`~repro.perfmodel.evaluator.Evaluator` protocol,
    so every existing consumer (``ExplorationEngine``, ``SweepEngine``,
    baselines, benches) can be handed a sharded evaluator unchanged.

    Parameters
    ----------
    base:
        The local evaluator each worker runs (``mode='process'`` workers
        rebuild an equivalent one from its models).
    workers:
        Shard fan-out.  ``workers=1`` always evaluates in-process.
    mode:
        One of ``auto | inline | thread | process | device | socket``
        (``auto`` = ``inline`` for one worker, ``thread`` otherwise).
        ``socket`` dispatches to remote ``repro.serve`` worker daemons
        and requires ``addresses=``.
    addresses:
        ``mode='socket'`` only: ``[(host, port), ...]`` of running
        ``python -m repro.serve.worker`` daemons.  ``workers`` defaults
        to ``len(addresses)`` and is clamped to it; the pool owns the
        liveness registry (heartbeats ride the wire), and this evaluator
        shares it instead of creating its own.
    min_shard_rows:
        Never split below this many designs per shard — tiny batches stay
        on one worker instead of paying fan-out overhead.
    retries:
        Re-dispatches allowed per shard after worker failures (shorthand
        for the default ``retry_policy``'s budget).
    retry_policy:
        Full :class:`~repro.runtime.fault.RetryPolicy` controlling the
        per-shard retry budget and the jittered exponential backoff slept
        before each re-dispatch.  Defaults to ``RetryPolicy(max_retries=
        retries, retryable=(Exception,))`` — any shard failure retryable,
        no backoff (the historical behaviour).
    shard_timeout_s:
        Absolute deadline per shard dispatch.  A dispatch still pending
        past it is declared LOST (not merely slow): the future is
        abandoned, the worker slot evicted, and the shard re-dispatched,
        consuming retry budget.  ``None`` (default) disables timeouts.
    straggler_factor / straggler_min_s:
        A pending shard is speculatively re-dispatched once it has been
        outstanding longer than ``max(straggler_min_s, factor x median
        completed-shard time)``.  ``speculate=False`` disables it.
        Speculation never consumes the failure-retry budget — the twin
        carries the same attempt number as its original.
    cold_straggler_s:
        Speculation deadline for the FIRST wave, before any shard has
        completed (no median exists yet to scale from) — generous by
        default so cold-start compiles never trigger spurious twins.
    fault_plan:
        Optional :class:`~repro.distributed.faults.FaultPlan`; wraps the
        pool in a :class:`~repro.distributed.faults.ChaosPool` so the
        whole retry / timeout / eviction path can be exercised
        deterministically.
    elastic / max_workers:
        ``elastic=True`` resizes the pool after dead-worker eviction via
        :func:`~repro.runtime.elastic.plan_elastic_pool` (bounded by
        ``max_workers``, default the initial ``workers``).
    validate:
        Receiver-side shard integrity check (row count, finite, strictly
        positive area/latency); a failing shard raises
        :class:`~repro.distributed.faults.WorkerFault` into the retry
        path.  On by default.
    registry / tracer / clock:
        Observability hooks (:mod:`repro.obs`): a shared
        :class:`~repro.obs.metrics.MetricsRegistry` for the traffic
        instruments, a :class:`~repro.obs.trace.Tracer` for per-shard
        causal spans (default: the free no-op tracer), and an injectable
        clock for deterministic timing under test.  All three are also
        handed to the socket pool so wire spans and heartbeat RTT land
        in the same registry/trace.
    """

    def __init__(self, base, *, workers: Optional[int] = None,
                 mode: str = "auto",
                 addresses: Optional[List[Tuple[str, int]]] = None,
                 membership=None,
                 insecure: bool = False,
                 keyring=None, key_id: Optional[str] = None,
                 ssl_context=None,
                 max_frame_bytes: Optional[int] = None,
                 min_shard_rows: int = 1, retries: int = 2,
                 retry_policy: Optional[RetryPolicy] = None,
                 shard_timeout_s: Optional[float] = None,
                 straggler_factor: float = 4.0, straggler_min_s: float = 0.05,
                 cold_straggler_s: float = 60.0, speculate: bool = True,
                 fault_plan: Optional[FaultPlan] = None,
                 heartbeat_timeout_s: float = 30.0,
                 elastic: bool = False, max_workers: Optional[int] = None,
                 validate: bool = True,
                 registry: Optional[MetricsRegistry] = None,
                 tracer=None, clock: Optional[Clock] = None):
        base = as_evaluator(base)
        if not hasattr(base, "models"):
            raise TypeError("ShardedEvaluator needs a model-backed evaluator")
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        if addresses is not None and mode != "socket":
            raise ValueError("addresses= is only meaningful with "
                             "mode='socket'")
        if membership is not None and mode != "socket":
            raise ValueError("membership= is only meaningful with "
                             "mode='socket'")
        self.base = base
        self.space = base.space
        self.tier = base.tier
        if workers is None:
            workers = len(addresses) if addresses else 2
        self.workers = max(1, int(workers))
        if mode == "socket":
            if not addresses and membership is None:
                raise ValueError("mode='socket' needs addresses="
                                 "[(host, port), ...] of running "
                                 "`python -m repro.serve.worker` daemons "
                                 "or membership= (a MembershipView workers "
                                 "announce to)")
            if addresses:
                self.workers = min(self.workers, len(addresses))
        elif self.workers == 1:
            mode = "inline"                    # the in-process fallback
        elif mode == "auto":
            mode = "thread"
        self.mode = mode
        # observability: one registry/tracer/clock shared with the pool so
        # heartbeat RTT and wire spans land next to the shard instruments
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NOOP
        self._clock: Clock = clock if clock is not None else time.monotonic
        if mode == "socket":
            from repro.serve.pool import SocketPool
            raw_pool = SocketPool(base,
                                  self.workers if addresses else None,
                                  addresses=addresses,
                                  membership=membership,
                                  insecure=insecure, keyring=keyring,
                                  key_id=key_id, ssl_context=ssl_context,
                                  max_frame_bytes=max_frame_bytes,
                                  heartbeat_timeout_s=heartbeat_timeout_s,
                                  metrics=self.metrics, tracer=self.tracer,
                                  clock=self._clock)
            if membership is not None:
                # lease-driven topology: the pool's view of the fleet is
                # authoritative, not the construction-time count
                self.workers = max(1, raw_pool.workers)
        else:
            raw_pool = _POOLS[mode](base, self.workers)
        self._raw_pool = raw_pool
        self._pool = (ChaosPool(raw_pool, fault_plan)
                      if fault_plan is not None else raw_pool)
        self.fault_plan = fault_plan
        self.membership = membership
        self.min_shard_rows = max(1, int(min_shard_rows))
        self.retries = int(retries)
        self.retry_policy = (retry_policy if retry_policy is not None
                             else RetryPolicy(max_retries=self.retries,
                                              retryable=(Exception,)))
        self.shard_timeout_s = (None if shard_timeout_s is None
                                else float(shard_timeout_s))
        self.straggler_factor = float(straggler_factor)
        self.straggler_min_s = float(straggler_min_s)
        self.cold_straggler_s = float(cold_straggler_s)
        self.speculate = bool(speculate)
        self.validate = bool(validate)
        self.elastic = bool(elastic)
        self.max_workers = max(self.workers, int(max_workers)
                               if max_workers is not None else self.workers)
        # worker liveness: slots 0..workers-1, beaten on shard completion.
        # A socket pool owns its registry (wire heartbeats + reconnects
        # drive it) and this evaluator shares it; local pools get a fresh
        # one driven by shard completions.
        pool_registry = getattr(raw_pool, "registry", None)
        self._pool_owns_registry = pool_registry is not None
        self.registry = (pool_registry if pool_registry is not None
                         else WorkerRegistry(timeout_s=heartbeat_timeout_s,
                                             now=self._clock))
        for s in range(self.workers):
            self.registry.register(s)
        self._dispatch_no = 0               # round-robin slot attribution
        # traffic instruments (int-valued properties below keep the old
        # `ev.retried`-style attribute surface intact)
        m = self.metrics
        self._c_dispatches = m.counter(
            "sharded_dispatches", "logical fused requests served")
        self._c_worker_dispatches = m.counter(
            "sharded_worker_dispatches", "shard tasks sent to workers")
        self._c_retried = m.counter(
            "sharded_retried", "shard retries after failures")
        self._c_straggler = m.counter(
            "sharded_straggler_redispatches", "speculative twin dispatches")
        self._c_timeouts = m.counter(
            "sharded_timeouts", "shards declared lost past the deadline")
        self._c_corrupt = m.counter(
            "sharded_corrupt_rejected", "shards failing the integrity check")
        self._c_resizes = m.counter(
            "sharded_resizes", "elastic pool resizes applied")
        self._c_quota_rerouted = m.counter(
            "sharded_quota_rerouted",
            "shards rerouted after worker quota refusals")
        self._h_shard = m.histogram(
            "sharded_shard_s", "completed-shard wall time (s) by worker slot",
            labelnames=("slot",))

    # -- traffic counters (registry-backed, old attribute surface) -------
    @property
    def dispatches(self) -> int:
        return int(self._c_dispatches.value())

    @property
    def worker_dispatches(self) -> int:
        return int(self._c_worker_dispatches.value())

    @property
    def retried(self) -> int:
        return int(self._c_retried.value())

    @property
    def straggler_redispatches(self) -> int:
        return int(self._c_straggler.value())

    @property
    def timeouts(self) -> int:
        return int(self._c_timeouts.value())

    @property
    def corrupt_rejected(self) -> int:
        return int(self._c_corrupt.value())

    @property
    def resizes(self) -> int:
        return int(self._c_resizes.value())

    @property
    def quota_rerouted(self) -> int:
        return int(self._c_quota_rerouted.value())

    # -- identity / protocol surface -----------------------------------
    @property
    def workloads(self) -> Tuple[str, ...]:
        return self.base.workloads

    @property
    def models(self):
        return self.base.models

    @property
    def backend(self):
        return getattr(self.base, "backend", None)

    @property
    def scenarios(self):
        return getattr(self.base, "scenarios", None)

    # -- public API -----------------------------------------------------
    def evaluate(self, request: EvalRequest) -> PPAReport:
        idx = np.atleast_2d(np.asarray(request.idx, dtype=np.int32))
        n = idx.shape[0]
        if self.membership is not None:
            # lease-driven fleets grow/shrink between requests: sync the
            # pool's slot view and shard to the CURRENT worker count
            self._raw_pool._sync_membership()
            self.workers = max(1, self._raw_pool.workers)
        n_shards = min(self.workers, max(1, n // self.min_shard_rows))
        self._c_dispatches.inc()
        tr = self.tracer
        with tr.span("sharded.evaluate", rows=n, mode=self.mode,
                     detail=request.detail) as sp:
            if ((self.mode == "inline" or n_shards <= 1)
                    and self.fault_plan is None and self.mode != "socket"):
                self._c_worker_dispatches.inc()
                return self.base.evaluate(
                    EvalRequest(idx, request.detail, request.workloads))
            # under a fault plan even single-shard requests route through
            # the pool so injection + recovery cover the inline path too;
            # socket mode ALWAYS rides the pool — offloading is the point
            payloads = [ShardPayload(s, request.detail, request.workloads)
                        for s in np.array_split(idx, max(1, n_shards))]
            if tr.enabled:
                sp.attrs["shards"] = len(payloads)
            parts = self._gather(payloads)
            with tr.span("sharded.reassemble", shards=len(parts)):
                return concat_reports(parts)

    def objectives(self, idx: np.ndarray) -> np.ndarray:
        return self.evaluate(EvalRequest(idx, detail="objectives")).objectives

    def ppa(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="ppa"))

    def stalls(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="stalls"))

    def __call__(self, idx: np.ndarray) -> np.ndarray:
        return self.objectives(idx)

    def close(self) -> None:
        self._pool.close()

    def resize(self, workers: int) -> None:
        """Resize the worker pool; replacement slots RE-register with the
        liveness registry, removed slots are evicted."""
        workers = max(1, min(int(workers), self.max_workers))
        if workers == self.workers:
            return
        old = self.workers
        self._pool.resize(workers)
        self.workers = workers
        self._c_resizes.inc()
        if self._pool_owns_registry:
            return                     # the pool's reconnect/close path
        for s in range(workers):       # maintains its registry itself
            self.registry.register(s)          # fresh/replacement slots
        for s in range(workers, old):
            self.registry.mark_dead(s)         # shrunk-away slots
        self.registry.evict_dead()

    # -- fault plumbing --------------------------------------------------
    def _check_shard(self, payload: ShardPayload, rep: PPAReport) -> None:
        """Receiver-side integrity check: a corrupted payload (wrong row
        count, non-finite or non-positive values) raises WorkerFault into
        the retry path instead of silently poisoning the merged report."""
        n = payload.idx.shape[0]
        area = np.asarray(rep.area)
        ok = (area.shape[0] == n and bool(np.isfinite(area).all())
              and bool((area > 0).all()))
        if ok:
            for nm in rep.workloads:
                lat = np.asarray(rep.latency[nm])
                if (lat.shape[0] != n or not np.isfinite(lat).all()
                        or bool((lat <= 0).any())):
                    ok = False
                    break
        if not ok:
            self._c_corrupt.inc()
            raise WorkerFault(f"corrupt shard payload rejected "
                              f"({n} rows, mode={self.mode!r})")

    def _on_worker_failure(self, slot: int, outstanding: int) -> None:
        """Crash/timeout attribution: evict the slot, re-register its
        replacement (pools backfill workers), optionally resize."""
        self.registry.mark_dead(slot)
        self.registry.evict_dead()
        if self.elastic:
            plan = plan_elastic_pool(len(self.registry), outstanding,
                                     min_workers=1,
                                     max_workers=self.max_workers)
            if plan.workers != self.workers:
                self.resize(plan.workers)
                return
        if self._pool_owns_registry:
            # the socket pool re-registers the slot itself when the
            # connection actually comes back — a blind re-register here
            # would claim liveness the wire has not proven
            return
        # executor pools replace dead workers transparently — the slot's
        # replacement re-registers under the same id
        self.registry.register(slot)

    # -- shard dispatch: retry + timeout + straggler speculation ---------
    def _gather(self, payloads: List[ShardPayload]) -> List[PPAReport]:
        policy = self.retry_policy
        clock = self._clock
        tr = self.tracer
        results: List[Optional[PPAReport]] = [None] * len(payloads)
        # fut -> (shard, attempt, worker slot, absolute deadline)
        pending: Dict[Future, Tuple[int, int, int, float]] = {}
        started: Dict[Future, float] = {}
        # fut -> detached shard span (finished out of order as futures
        # resolve; every exit path closes it: ok / error / lost)
        spans: Dict[Future, object] = {}
        speculated: set = set()
        durations: List[float] = []
        quota_reroutes: Dict[int, int] = {}
        parent_ctx = tr.current_ctx()          # the sharded.evaluate span

        def submit(i: int, attempt: int) -> None:
            slot = self._dispatch_no % self.workers
            self._dispatch_no += 1
            if tr.enabled:
                sp = tr.start("shard", detached=True, parent=parent_ctx,
                              shard=i, attempt=attempt, slot=slot)
                # current during the pool submit so the wire span (socket
                # mode) parents under this shard attempt
                with tr.activate(sp):
                    fut = self._pool.submit(payloads[i])
                spans[fut] = sp
            else:
                fut = self._pool.submit(payloads[i])
            now = clock()
            started[fut] = now
            deadline = (now + self.shard_timeout_s
                        if self.shard_timeout_s else math.inf)
            pending[fut] = (i, attempt, slot, deadline)
            self._c_worker_dispatches.inc()

        def close_span(fut: Future, status: str, reason: str = "") -> None:
            sp = spans.pop(fut, None)
            if sp is None:
                return
            if status == "lost":
                tr.lose(sp, reason)
            else:
                if reason:
                    sp.attrs["error"] = reason
                tr.finish(sp, status=None if status == "ok" else status)

        def fail(i: int, attempt: int, slot: int, exc: Optional[BaseException],
                 what: str) -> None:
            if isinstance(exc, QuotaExceeded) and \
                    quota_reroutes.get(i, 0) < max(1, self.workers):
                # the worker refused by POLICY — it is healthy and the
                # shard is fine: reroute to the next slot at the same
                # attempt, no backoff, no retry budget, no eviction
                # (bounded per shard so an all-refusing fleet still
                # falls through to the normal retry/raise path)
                quota_reroutes[i] = quota_reroutes.get(i, 0) + 1
                self._c_quota_rerouted.inc()
                submit(i, attempt)
                return
            self._on_worker_failure(
                slot, sum(1 for r in results if r is None))
            if attempt >= policy.max_retries:
                raise RuntimeError(
                    f"shard {i} {what} after {attempt + 1} attempts "
                    f"on the {self.mode!r} pool") from exc
            self._c_retried.inc()
            d = policy.delay(attempt)
            if d:
                time.sleep(d)
            submit(i, attempt + 1)

        for i in range(len(payloads)):
            submit(i, 0)
        while any(r is None for r in results):
            now = clock()
            # next wake-up: earliest shard deadline or straggler threshold
            thresh = (max(self.straggler_min_s, self.straggler_factor
                          * float(np.median(durations)))
                      if durations else self.cold_straggler_s)
            wake = math.inf
            for fut, (i, _a, _s, deadline) in pending.items():
                if results[i] is not None:
                    continue
                wake = min(wake, deadline)
                if self.speculate and i not in speculated:
                    wake = min(wake, started[fut] + thresh)
            timeout = None if wake is math.inf else max(0.0, wake - now)
            done, _ = wait(list(pending), timeout=timeout,
                           return_when=FIRST_COMPLETED)
            now = clock()
            for fut in done:
                i, attempt, slot, _deadline = pending.pop(fut)
                t0 = started.pop(fut, now)
                if results[i] is not None:
                    # a faster twin already landed; this one's work is moot
                    close_span(fut, "lost", "lost the twin race")
                    continue
                try:
                    rep = fut.result()
                    if self.validate:
                        self._check_shard(payloads[i], rep)
                except policy.retryable as exc:
                    close_span(fut, "error", str(exc))
                    fail(i, attempt, slot, exc, "failed")
                    continue
                close_span(fut, "ok")
                results[i] = rep
                durations.append(now - t0)
                self._h_shard.observe(now - t0, slot=slot)
                self.registry.beat(slot)
            # shard timeouts: the dispatch is LOST, not merely slow —
            # abandon the future, evict the slot, consume retry budget
            for fut, (i, attempt, slot, deadline) in list(pending.items()):
                if results[i] is not None or now < deadline:
                    continue
                pending.pop(fut)
                started.pop(fut, None)
                fut.cancel()
                close_span(fut, "lost", "shard timeout")
                self._c_timeouts.inc()
                fail(i, attempt, slot, None, "timed out")
            # straggler speculation: one twin per slow shard, at the SAME
            # attempt (speculation never consumes the retry budget)
            if self.speculate:
                for fut, (i, attempt, _s, _d) in list(pending.items()):
                    if (results[i] is None and i not in speculated
                            and now - started.get(fut, now) >= thresh):
                        speculated.add(i)
                        self._c_straggler.inc()
                        submit(i, attempt)
        for fut in pending:                    # abandoned twins
            fut.cancel()
            close_span(fut, "lost", "abandoned twin")
        return results
