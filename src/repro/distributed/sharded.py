"""Sharded multi-worker evaluation behind the Evaluator protocol.

:class:`ShardedEvaluator` splits one :class:`~repro.perfmodel.evaluator.
EvalRequest`'s design batch into N contiguous shards, dispatches them to a
worker pool, and reassembles a single :class:`~repro.perfmodel.evaluator.
PPAReport` **bit-identical** to the local :class:`~repro.perfmodel.
evaluator.ModelEvaluator` on the same request (every per-design value is
row-wise, so shard boundaries never change a float).

Worker pools
------------
``inline``   — the ``workers=1`` in-process fallback: evaluate on the
               caller's thread (zero overhead, always available).
``thread``   — a thread pool over ONE process-local evaluator; jitted
               executables are shared, shards overlap host pre/post work.
               The default for ``workers > 1``.
``process``  — spawned worker processes, each constructing its own
               evaluator from a pickled (model class, workload, space)
               spec — the multi-host template: nothing is shared but the
               request/report wire format.
``device``   — thread pool that pins shard k to ``jax.devices()[k % D]``
               (round-robin), for hosts with more than one accelerator.

Fault handling
--------------
A shard that raises is retried up to ``retries`` times on a fresh worker;
a straggler — a shard still pending after ``straggler_factor`` x the
median completed-shard time — is speculatively re-dispatched and whichever
twin finishes first wins (results are identical by construction, so the
race is benign).  ``worker_dispatches`` / ``retried`` /
``straggler_redispatches`` count the traffic.
"""
from __future__ import annotations

import itertools
import pickle
import time
from concurrent.futures import (FIRST_COMPLETED, Future, ProcessPoolExecutor,
                                ThreadPoolExecutor, wait)
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.perfmodel.evaluator import (EvalRequest, ModelEvaluator, PPAReport,
                                       as_evaluator)

MODES = ("auto", "inline", "thread", "process", "device")


@dataclass(frozen=True)
class ShardPayload:
    """One shard of an EvalRequest on the worker wire format."""
    idx: np.ndarray
    detail: str
    workloads: Optional[Tuple[str, ...]]


def _eval_payload(evaluator, payload: ShardPayload) -> PPAReport:
    return evaluator.evaluate(EvalRequest(payload.idx, payload.detail,
                                          payload.workloads))


def concat_reports(parts: List[PPAReport]) -> PPAReport:
    """Reassemble shard reports into one batch report (shard order)."""
    first = parts[0]
    if len(parts) == 1:
        return first
    names = first.workloads

    def cat(field):
        return {nm: np.concatenate([getattr(p, field)[nm] for p in parts])
                for nm in names}

    rep = PPAReport(workloads=names, detail=first.detail,
                    area=np.concatenate([p.area for p in parts]),
                    latency=cat("latency"))
    if first.op_time is not None:
        rep.op_time = cat("op_time")
        rep.op_names = first.op_names
    if first.stall is not None:
        rep.stall = cat("stall")
        rep.op_class = cat("op_class")
    return rep


# ---------------------------------------------------------------------------
# worker pools
# ---------------------------------------------------------------------------

class _InlinePool:
    """workers=1 fallback: evaluate on the caller's thread."""
    mode = "inline"

    def __init__(self, base, workers: int = 1):
        self._base = base
        self.workers = 1

    def submit(self, payload: ShardPayload) -> Future:
        fut: Future = Future()
        try:
            fut.set_result(_eval_payload(self._base, payload))
        except BaseException as exc:            # surfaced via fut.result()
            fut.set_exception(exc)
        return fut

    def close(self) -> None:
        pass


class _ThreadPool:
    """Thread workers over one shared process-local evaluator."""
    mode = "thread"

    def __init__(self, base, workers: int):
        self._base = base
        self.workers = int(workers)
        self._ex = ThreadPoolExecutor(max_workers=self.workers,
                                      thread_name_prefix="shard-eval")

    def submit(self, payload: ShardPayload) -> Future:
        return self._ex.submit(_eval_payload, self._base, payload)

    def close(self) -> None:
        self._ex.shutdown(wait=False, cancel_futures=True)


class _DevicePool(_ThreadPool):
    """Thread workers, shard k pinned to jax device k % D (round-robin)."""
    mode = "device"

    def __init__(self, base, workers: int):
        super().__init__(base, workers)
        import jax
        devs = jax.devices()
        self._devices = [devs[i % len(devs)] for i in range(self.workers)]
        self._rr = itertools.count()

    def submit(self, payload: ShardPayload) -> Future:
        import jax
        dev = self._devices[next(self._rr) % self.workers]

        def task():
            with jax.default_device(dev):
                return _eval_payload(self._base, payload)

        return self._ex.submit(task)


# -- process pool: workers rebuild the evaluator from a pickled spec --------

_WORKER_EVALUATOR: Optional[ModelEvaluator] = None


def _worker_spec(base: ModelEvaluator) -> bytes:
    """(model class, workload, space, tier, backend) — everything a spawned
    worker needs to reconstruct an equivalent evaluator from scratch."""
    return pickle.dumps({
        "models": {nm: (type(m), m.wl) for nm, m in base.models.items()},
        "space": base.space,
        "tier": base.tier,
        "backend": base.backend,
        "scenarios": getattr(base, "scenarios", None),
        "stacked": getattr(base, "stacked", None),
    })


def _process_init(spec_bytes: bytes) -> None:
    global _WORKER_EVALUATOR
    spec = pickle.loads(spec_bytes)
    models = {nm: cls(wl, spec["space"])
              for nm, (cls, wl) in spec["models"].items()}
    _WORKER_EVALUATOR = ModelEvaluator(models, tier=spec["tier"],
                                       backend=spec["backend"],
                                       scenarios=spec.get("scenarios"),
                                       stacked=spec.get("stacked"))


def _process_eval(payload: ShardPayload) -> PPAReport:
    return _eval_payload(_WORKER_EVALUATOR, payload)


class _ProcessPool:
    """Spawned local processes — the multi-host sharding template."""
    mode = "process"

    def __init__(self, base, workers: int):
        if not isinstance(base, ModelEvaluator):
            raise TypeError("mode='process' needs a ModelEvaluator base "
                            "(workers rebuild it from its models)")
        import multiprocessing as mp
        self.workers = int(workers)
        self._ex = ProcessPoolExecutor(
            max_workers=self.workers,
            mp_context=mp.get_context("spawn"),
            initializer=_process_init, initargs=(_worker_spec(base),))

    def submit(self, payload: ShardPayload) -> Future:
        return self._ex.submit(_process_eval, payload)

    def close(self) -> None:
        self._ex.shutdown(wait=False, cancel_futures=True)


_POOLS = {"inline": _InlinePool, "thread": _ThreadPool,
          "process": _ProcessPool, "device": _DevicePool}


# ---------------------------------------------------------------------------
# the sharded evaluator
# ---------------------------------------------------------------------------

class ShardedEvaluator:
    """Fan one EvalRequest across N workers; gather one PPAReport.

    Implements the :class:`~repro.perfmodel.evaluator.Evaluator` protocol,
    so every existing consumer (``ExplorationEngine``, ``SweepEngine``,
    baselines, benches) can be handed a sharded evaluator unchanged.

    Parameters
    ----------
    base:
        The local evaluator each worker runs (``mode='process'`` workers
        rebuild an equivalent one from its models).
    workers:
        Shard fan-out.  ``workers=1`` always evaluates in-process.
    mode:
        One of ``auto | inline | thread | process | device`` (``auto`` =
        ``inline`` for one worker, ``thread`` otherwise).
    min_shard_rows:
        Never split below this many designs per shard — tiny batches stay
        on one worker instead of paying fan-out overhead.
    retries:
        Re-dispatches allowed per shard after worker failures.
    straggler_factor / straggler_min_s:
        A pending shard is speculatively re-dispatched once it has been
        outstanding longer than ``max(straggler_min_s, factor x median
        completed-shard time)``.  ``speculate=False`` disables it.
        Speculation never consumes the failure-retry budget — the twin
        carries the same attempt number as its original.
    cold_straggler_s:
        Speculation deadline for the FIRST wave, before any shard has
        completed (no median exists yet to scale from) — generous by
        default so cold-start compiles never trigger spurious twins.
    """

    def __init__(self, base, *, workers: int = 2, mode: str = "auto",
                 min_shard_rows: int = 1, retries: int = 2,
                 straggler_factor: float = 4.0, straggler_min_s: float = 0.05,
                 cold_straggler_s: float = 60.0, speculate: bool = True):
        base = as_evaluator(base)
        if not hasattr(base, "models"):
            raise TypeError("ShardedEvaluator needs a model-backed evaluator")
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self.base = base
        self.space = base.space
        self.tier = base.tier
        self.workers = max(1, int(workers))
        if self.workers == 1:
            mode = "inline"                    # the in-process fallback
        elif mode == "auto":
            mode = "thread"
        self.mode = mode
        self._pool = _POOLS[mode](base, self.workers)
        self.min_shard_rows = max(1, int(min_shard_rows))
        self.retries = int(retries)
        self.straggler_factor = float(straggler_factor)
        self.straggler_min_s = float(straggler_min_s)
        self.cold_straggler_s = float(cold_straggler_s)
        self.speculate = bool(speculate)
        # traffic counters
        self.dispatches = 0                 # logical fused requests served
        self.worker_dispatches = 0          # shard tasks sent to workers
        self.retried = 0                    # shard retries after failures
        self.straggler_redispatches = 0     # speculative twin dispatches

    # -- identity / protocol surface -----------------------------------
    @property
    def workloads(self) -> Tuple[str, ...]:
        return self.base.workloads

    @property
    def models(self):
        return self.base.models

    @property
    def backend(self):
        return getattr(self.base, "backend", None)

    @property
    def scenarios(self):
        return getattr(self.base, "scenarios", None)

    # -- public API -----------------------------------------------------
    def evaluate(self, request: EvalRequest) -> PPAReport:
        idx = np.atleast_2d(np.asarray(request.idx, dtype=np.int32))
        n = idx.shape[0]
        n_shards = min(self.workers, max(1, n // self.min_shard_rows))
        self.dispatches += 1
        if self.mode == "inline" or n_shards <= 1:
            self.worker_dispatches += 1
            return self.base.evaluate(
                EvalRequest(idx, request.detail, request.workloads))
        payloads = [ShardPayload(s, request.detail, request.workloads)
                    for s in np.array_split(idx, n_shards)]
        return concat_reports(self._gather(payloads))

    def objectives(self, idx: np.ndarray) -> np.ndarray:
        return self.evaluate(EvalRequest(idx, detail="objectives")).objectives

    def ppa(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="ppa"))

    def stalls(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="stalls"))

    def __call__(self, idx: np.ndarray) -> np.ndarray:
        return self.objectives(idx)

    def close(self) -> None:
        self._pool.close()

    # -- shard dispatch with retry + straggler speculation --------------
    def _gather(self, payloads: List[ShardPayload]) -> List[PPAReport]:
        results: List[Optional[PPAReport]] = [None] * len(payloads)
        pending: Dict[Future, Tuple[int, int]] = {}   # fut -> (shard, attempt)
        started: Dict[Future, float] = {}
        speculated: set = set()
        durations: List[float] = []

        def submit(i: int, attempt: int) -> None:
            fut = self._pool.submit(payloads[i])
            started[fut] = time.perf_counter()
            pending[fut] = (i, attempt)
            self.worker_dispatches += 1

        for i in range(len(payloads)):
            submit(i, 0)
        while any(r is None for r in results):
            timeout = None
            if self.speculate and any(i not in speculated
                                      for i, r in enumerate(results)
                                      if r is None):
                # cold first wave: no median to scale from yet — use the
                # generous absolute deadline instead of waiting forever
                timeout = (max(self.straggler_min_s, self.straggler_factor
                               * float(np.median(durations)))
                           if durations else self.cold_straggler_s)
            done, _ = wait(list(pending), timeout=timeout,
                           return_when=FIRST_COMPLETED)
            now = time.perf_counter()
            if not done:
                # every outstanding shard is a straggler: one twin each,
                # at the SAME attempt (speculation is not a failure and
                # must not consume the retry budget)
                for fut, (i, attempt) in list(pending.items()):
                    if results[i] is None and i not in speculated:
                        speculated.add(i)
                        self.straggler_redispatches += 1
                        submit(i, attempt)
                continue
            for fut in done:
                i, attempt = pending.pop(fut)
                if results[i] is not None:
                    continue                   # a faster twin already landed
                try:
                    rep = fut.result()
                except Exception as exc:
                    if attempt >= self.retries:
                        raise RuntimeError(
                            f"shard {i} failed after {attempt + 1} attempts "
                            f"on the {self.mode!r} pool") from exc
                    self.retried += 1
                    submit(i, attempt + 1)
                    continue
                results[i] = rep
                durations.append(now - started.get(fut, now))
        for fut in pending:                    # abandoned twins
            fut.cancel()
        return results
