"""Async evaluation service: request queue + coalescing batcher + futures.

:class:`EvalService` generalizes :meth:`~repro.core.explore.
ExplorationEngine.prefetch` from "one runner batches its own candidates"
to "ANY concurrent clients coalesce": K campaigns, interleaved baseline
sweeps and benchmark probes all :meth:`~EvalService.submit` their
:class:`~repro.perfmodel.evaluator.EvalRequest`\\ s, and each
:meth:`~EvalService.tick` drains the queue into ONE fused dispatch on the
underlying evaluator — deduplicating design rows across clients and
resolving every request's future from the shared result.

* **Coalescing**: a tick evaluates the union of queued rows once, at the
  maximum detail level any queued request asked for (``objectives`` <
  ``ppa`` < ``stalls`` — latencies are bit-identical across levels, so
  higher detail only adds fields).
* **Shared cross-client cache**: every evaluated design row lands in ONE
  :class:`~repro.perfmodel.evaluator.RowCache` (``service.row_cache``) —
  the same object :class:`~repro.core.explore.ExplorationEngine` reads
  when its evaluator is a service, so there is one report cache in the
  process, not two.  A request whose rows are all cached at sufficient
  detail resolves at :meth:`~EvalService.submit` time with NO dispatch,
  whoever evaluated it first.
* **QoS tiers + per-client fairness**: requests queue per
  ``(tier, client)`` (``submit(..., tier="interactive" | "batch" |
  "scavenger", client=...)``) and the tick drains tiers by WEIGHTED
  DEFICIT round-robin (default weights 8 : 3 : 1): each drain pass
  credits every backlogged tier its weight and serves the tier with the
  largest accumulated credit, debiting the rows served — so interactive
  campaign steps preempt bulk sweep traffic *proportionally*, not
  absolutely.  An anti-starvation floor grants every backlogged tier one
  request per tick before weights apply, so scavenger throughput stays
  > 0 under saturating interactive load.  Within a tier, clients are
  served round-robin, one request per client per pass, rotating the
  starting client — a chatty client cannot starve its tier peers.
  ``telemetry()["tiers"]`` reports per-tier served/queued counts and
  p50/p99 queue-to-resolve latency.
* **Evaluator protocol**: the service itself implements ``evaluate`` /
  ``objectives`` / ``workloads`` — hand it to ``CampaignRunner``,
  ``LuminaDSE``, a baseline driver or a bench wherever an ``Evaluator``
  is expected.  A synchronous ``evaluate`` call self-ticks when its rows
  are not already resolved.
* **Ticking**: call :meth:`tick` explicitly (deterministic — what the
  round-driven ``CampaignRunner`` does), or construct with
  ``autostart=True`` for a background batcher thread that ticks after a
  short coalescing window.

The underlying evaluator may itself be a :class:`~repro.distributed.
sharded.ShardedEvaluator`, composing "coalesce across clients" with
"shard across workers".
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import Clock, CounterView, MetricsRegistry
from repro.obs.trace import NOOP
from repro.perfmodel.evaluator import (DETAILS, EvalRequest, PPAReport,
                                       RowCache, as_evaluator)

_DETAIL_LEVEL = {name: i for i, name in enumerate(DETAILS)}


DEGRADE_RUNGS = ("narrow", "proxy", "cached")

# QoS tiers, highest priority first; the drain order of the
# anti-starvation floor and the tie-break order of the deficit scheduler
QOS_TIERS = ("interactive", "batch", "scavenger")

# default weighted-deficit drain shares (rows per credit pass)
DEFAULT_TIER_WEIGHTS = {"interactive": 8.0, "batch": 3.0, "scavenger": 1.0}

# cap banked credit at this many times the tier weight: an idle tier can
# bank a short burst of priority, not an unbounded IOU
_DEFICIT_BURST = 64.0


@dataclass
class _Pending:
    idx: np.ndarray                      # (n, n_params) int32
    detail: str
    names: Tuple[str, ...]
    future: Future
    client: str
    tier: str = "batch"
    deadline: Optional[float] = None     # absolute monotonic deadline
    t_submit: float = 0.0                # monotonic submit time (latency)
    span: object = None                  # detached service.request span


def _assemble(rows: List[PPAReport], names: Tuple[str, ...],
              detail: str) -> PPAReport:
    """Stack cached single-row reports into one response, restricted to the
    request's workloads and demoted to its detail level."""
    rep = PPAReport(
        workloads=names, detail=detail,
        area=np.concatenate([r.area for r in rows]),
        latency={nm: np.concatenate([r.latency[nm] for r in rows])
                 for nm in names})
    if detail in ("ppa", "stalls"):
        rep.op_time = {nm: np.concatenate([r.op_time[nm] for r in rows])
                       for nm in names}
        rep.op_names = {nm: rows[0].op_names[nm] for nm in names}
    if detail == "stalls":
        rep.stall = {nm: np.concatenate([r.stall[nm] for r in rows])
                     for nm in names}
        rep.op_class = {nm: np.concatenate([r.op_class[nm] for r in rows])
                        for nm in names}
    return rep


class EvalService:
    """Coalescing evaluation front-end over one (possibly sharded) evaluator.

    Parameters
    ----------
    evaluator:
        Anything :func:`~repro.perfmodel.evaluator.as_evaluator` accepts —
        typically a :class:`~repro.perfmodel.evaluator.ModelEvaluator` or a
        :class:`~repro.distributed.sharded.ShardedEvaluator`.
    cache_rows:
        Bound on the shared per-design report cache (LRU beyond it).
        Ignored when an external ``cache`` is injected.
    cache:
        An existing :class:`~repro.perfmodel.evaluator.RowCache` to share
        (e.g. with another service over the same evaluator).
    max_rows_per_tick:
        Cap on FRESH design rows dispatched per tick.  None (default) =
        unbounded — every queued request resolves in one tick.  With a cap,
        the round-robin drain guarantees each client gets a request served
        before any client gets a second one.
    autostart:
        Start a background batcher thread that ticks whenever requests sit
        in the queue longer than ``window_s`` (the coalescing window).
        Without it, call :meth:`tick` yourself — synchronous ``evaluate``
        calls also self-tick.
    degrade:
        The graceful-degradation ladder walked when a fused dispatch
        fails (or a request's ``deadline_s`` expires), in order:

        * ``narrow`` — halve the sharded evaluator's worker pool
          (``resize``) and retry the dispatch, repeating down to one
          worker (worker-loss recovery);
        * ``proxy``  — retry the dispatch at ``objectives`` detail (the
          cheap proxy: responses are demoted but correct);
        * ``cached`` — serve each request from whatever detail the shared
          row cache holds (possibly shallower than asked).

        Only a request that exhausts every rung sees the evaluator's
        exception; ``service.degraded`` counts rung traffic and requests
        NEVER crash the tick.
    registry / tracer / clock:
        Observability hooks (:mod:`repro.obs`): the
        :class:`~repro.obs.metrics.MetricsRegistry` holding the traffic
        instruments (fresh per service by default), a
        :class:`~repro.obs.trace.Tracer` for tick/dispatch/request spans
        (default: the free no-op tracer), and an injectable clock for
        deterministic latency accounting under test.
    """

    def __init__(self, evaluator, *, cache_rows: int = 65_536,
                 cache: Optional[RowCache] = None,
                 max_rows_per_tick: Optional[int] = None,
                 autostart: bool = False, window_s: float = 0.002,
                 degrade: Tuple[str, ...] = DEGRADE_RUNGS,
                 tier_weights: Optional[Dict[str, float]] = None,
                 registry: Optional[MetricsRegistry] = None,
                 tracer=None, clock: Optional[Clock] = None):
        self.evaluator = as_evaluator(evaluator)
        self.space = self.evaluator.space
        self.tier = self.evaluator.tier
        self.window_s = float(window_s)
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NOOP
        self._clock: Clock = clock if clock is not None else time.monotonic
        self.max_rows_per_tick = (None if max_rows_per_tick is None
                                  else int(max_rows_per_tick))
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        # per-(tier, client) FIFO queues: tiers drain by weighted deficit,
        # clients within a tier round-robin
        self._queues: Dict[str, "OrderedDict[str, Deque[_Pending]]"] = {
            t: OrderedDict() for t in QOS_TIERS}
        self._rr = {t: 0 for t in QOS_TIERS}   # per-tier client rotation
        self._deficit = {t: 0.0 for t in QOS_TIERS}
        weights = dict(DEFAULT_TIER_WEIGHTS)
        if tier_weights:
            unknown = set(tier_weights) - set(QOS_TIERS)
            if unknown:
                raise ValueError(f"unknown QoS tiers {sorted(unknown)}; "
                                 f"choose from {QOS_TIERS}")
            for t, w in tier_weights.items():
                if float(w) <= 0:
                    raise ValueError(f"tier weight for {t!r} must be > 0")
                weights[t] = float(w)
        self.tier_weights = weights
        # THE shared cross-client design-row cache (ExplorationEngine reads
        # this same object when its evaluator is a service)
        self.row_cache: RowCache = (cache if cache is not None
                                    else RowCache(cache_rows))
        self._closed = False
        unknown_rungs = set(degrade) - set(DEGRADE_RUNGS)
        if unknown_rungs:
            raise ValueError(f"unknown degrade rungs {sorted(unknown_rungs)}; "
                             f"choose from {DEGRADE_RUNGS}")
        self.degrade = tuple(degrade)
        # traffic instruments — each takes its OWN lock on write, so no
        # increment needs the service lock (the PR 8 unlocked-shared-write
        # rule passes by construction).  Int-valued properties and
        # CounterView facades below keep the old attribute surface
        # (`svc.submits`, `svc.degraded["narrow"]`, `dict(svc.tier_served)`)
        # working bit-for-bit.
        m = self.metrics
        self._c_submits = m.counter(
            "service_submits", "requests received")
        self._c_cache_hits = m.counter(
            "service_cache_hits", "requests resolved straight from cache")
        self._c_fused = m.counter(
            "service_fused_dispatches", "ticks that reached the evaluator")
        self._c_coalesced = m.counter(
            "service_coalesced_requests", "requests resolved by a fused tick")
        self._c_degraded = m.counter(
            "service_degraded",
            "deadline demotions + degradation-ladder rung traffic",
            labelnames=("rung",))
        for rung in ("deadline",) + DEGRADE_RUNGS:
            self._c_degraded.touch(rung=rung)
        self._c_tier_served = m.counter(
            "service_tier_served", "requests resolved, by QoS tier",
            labelnames=("tier",))
        self._h_queue_lat = m.histogram(
            "service_queue_latency_s", "queue-to-resolve latency (s) by tier",
            labelnames=("tier",))
        for t in QOS_TIERS:
            self._c_tier_served.touch(tier=t)
            self._h_queue_lat.touch(tier=t)
        self._h_tick = m.histogram(
            "service_tick_s", "non-empty tick wall time (s)")
        self.degraded = CounterView(self._c_degraded)
        self.tier_served = CounterView(self._c_tier_served)
        self._batcher: Optional[threading.Thread] = None
        if autostart:
            self._batcher = threading.Thread(target=self._batch_loop,
                                             name="eval-service-batcher",
                                             daemon=True)
            self._batcher.start()

    # -- protocol surface ----------------------------------------------
    @property
    def workloads(self) -> Tuple[str, ...]:
        return self.evaluator.workloads

    @property
    def models(self):
        return self.evaluator.models

    @property
    def scenarios(self):
        return getattr(self.evaluator, "scenarios", None)

    @property
    def dispatches(self) -> int:
        """Fused device dispatches spent by the underlying evaluator."""
        return getattr(self.evaluator, "dispatches", 0)

    # -- traffic counters (registry-backed, old attribute surface) -------
    @property
    def submits(self) -> int:
        return int(self._c_submits.value())

    @property
    def cache_hits(self) -> int:
        return int(self._c_cache_hits.value())

    @property
    def fused_dispatches(self) -> int:
        return int(self._c_fused.value())

    @property
    def coalesced_requests(self) -> int:
        return int(self._c_coalesced.value())

    @property
    def cache_rows(self) -> int:
        return self.row_cache.capacity

    def _queued(self) -> int:
        return sum(len(q) for tier in self._queues.values()
                   for q in tier.values())

    def queued_rows(self) -> int:
        """Total design rows currently queued (admission-control signal:
        the gateway's backpressure check reads this)."""
        with self._lock:
            return sum(p.idx.shape[0] for tier in self._queues.values()
                       for q in tier.values() for p in q)

    # -- async API ------------------------------------------------------
    def submit(self, request: EvalRequest, *, client: str = "",
               tier: str = "batch",
               deadline_s: Optional[float] = None) -> Future:
        """Enqueue one request; the returned future resolves to a PPAReport.

        ``client`` names the submitting party for round-robin fairness
        (campaign label, bench name, ...); anonymous submitters share one
        lane.  ``tier`` picks the QoS lane (``interactive`` | ``batch`` |
        ``scavenger``) drained by weighted deficit.  Requests whose rows
        are ALL cached at sufficient detail resolve immediately (no
        queue, no dispatch) — the shared cross-client cache path.
        ``deadline_s`` bounds queue latency: a request still queued past
        it is DEGRADED (cached rows, then ``objectives`` proxy detail)
        rather than failed.
        """
        if tier not in QOS_TIERS:
            raise ValueError(f"tier must be one of {QOS_TIERS}, "
                             f"got {tier!r}")
        idx = np.atleast_2d(np.asarray(request.idx, dtype=np.int32))
        names = (self.workloads if request.workloads is None
                 else tuple(request.workloads))
        unknown = set(names) - set(self.workloads)
        if unknown:
            raise KeyError(f"unknown workloads {sorted(unknown)}; "
                           f"have {self.workloads}")
        now = self._clock()
        deadline = None if deadline_s is None else now + float(deadline_s)
        tr = self.tracer
        rsp = None
        if tr.enabled:
            # detached: resolved (finished) by whichever tick serves it
            rsp = tr.start("service.request", detached=True, tier=tier,
                           client=client, rows=int(idx.shape[0]),
                           detail=request.detail)
        pend = _Pending(idx, request.detail, names, Future(), client,
                        tier, deadline, now, rsp)
        with self._lock:
            if self._closed:
                if rsp is not None:
                    tr.lose(rsp, "service closed")
                raise RuntimeError("EvalService is closed")
            self._c_submits.inc()
            if self._try_resolve(pend):
                self._c_cache_hits.inc()
            else:
                self._queues[tier].setdefault(client, deque()).append(pend)
                self._cond.notify()
        return pend.future

    def _pop_tier(self, tier: str) -> Optional[_Pending]:
        """Pop ONE request from `tier`, round-robin across its clients
        (caller holds the lock)."""
        queues = self._queues[tier]
        clients = list(queues)
        if not clients:
            return None
        start = self._rr[tier] % len(clients)
        for off in range(len(clients)):
            client = clients[(start + off) % len(clients)]
            q = queues[client]
            if q:
                pend = q.popleft()
                if not q:
                    del queues[client]
                # next pop starts after the client just served (taken
                # modulo the then-current client count at read time)
                self._rr[tier] = start + off + 1
                return pend
        return None

    def _drain_fair(self) -> List[_Pending]:
        """Drain requests by QoS tier (caller holds the lock).

        Two phases per tick: (1) the ANTI-STARVATION FLOOR — every tier
        with queued work gets one request, highest priority first, even
        past ``max_rows_per_tick`` — a saturating interactive flood can
        slow the scavenger tier but never zero it; (2) WEIGHTED-DEFICIT
        round-robin — each pass credits every backlogged tier its weight,
        the largest-credit tier serves one request and is debited the
        rows it consumed, until the queues are empty or the planned row
        count reaches ``max_rows_per_tick``.  Credit is capped (a tier
        idle for an hour gets a burst, not an unbounded IOU) and resets
        when a tier's backlog clears.
        """
        picked: List[_Pending] = []
        rows = 0
        live = [t for t in QOS_TIERS if self._queues[t]]
        if not live:
            return picked
        for t in live:                         # the floor
            pend = self._pop_tier(t)
            if pend is not None:
                picked.append(pend)
                rows += pend.idx.shape[0]
        cap = self.max_rows_per_tick
        while cap is None or rows < cap:       # the weighted drain
            live = [t for t in QOS_TIERS if self._queues[t]]
            if not live:
                break
            for t in live:
                w = self.tier_weights[t]
                self._deficit[t] = min(self._deficit[t] + w,
                                       _DEFICIT_BURST * w)
            # max() scans QOS_TIERS order, so priority breaks credit ties
            t = max(live, key=lambda tt: self._deficit[tt])
            pend = self._pop_tier(t)
            if pend is None:
                break
            self._deficit[t] -= pend.idx.shape[0]
            picked.append(pend)
            rows += pend.idx.shape[0]
        for t in QOS_TIERS:
            if not self._queues[t]:
                self._deficit[t] = 0.0
        return picked

    def tick(self) -> int:
        """Drain the queue into ONE fused dispatch; resolve every future.

        Returns the number of design rows actually dispatched (0 when the
        queue was empty, fully cache-resident, or the dispatch failed).
        The fused dispatch runs OUTSIDE the service lock, so concurrent
        clients keep submitting (their requests form the next tick's
        batch).  A dispatch failure walks the ``degrade`` ladder (narrow
        the sharded pool -> objectives proxy -> cached rows) before ANY
        future sees an exception, so blocked ``result()`` callers — and
        the autostart batcher — always make progress.
        """
        tr = self.tracer
        if not tr.enabled:
            return self._tick_inner(None)
        with self._lock:
            if not any(self._queues[t] for t in QOS_TIERS):
                return 0                       # don't trace empty ticks
        t0 = self._clock()
        with tr.span("service.tick") as sp:
            rows = self._tick_inner(sp)
        self._h_tick.observe(self._clock() - t0)
        return rows

    def _tick_inner(self, sp) -> int:
        with self._lock:
            pending = self._drain_fair()
            if not pending:
                return 0
            now = self._clock()
            still: List[_Pending] = []
            for p in pending:
                if p.deadline is not None and now >= p.deadline:
                    # deadline pressure: cached rows first, else demote
                    # the request to the cheap proxy detail for this tick
                    if ("cached" in self.degrade
                            and self._try_resolve_degraded(p)):
                        self._c_degraded.inc(rung="deadline")
                        self._c_coalesced.inc()
                        continue
                    if p.detail != "objectives":
                        p.detail = "objectives"
                        self._c_degraded.inc(rung="deadline")
                still.append(p)
            pending = still
            if not pending:
                return 0
            level = max(_DETAIL_LEVEL[p.detail] for p in pending)
            detail = DETAILS[level]
            fresh_rows: List[np.ndarray] = []
            fresh_keys: List[bytes] = []
            seen: set = set()
            for p in pending:
                for row in p.idx:
                    key = RowCache.key(row)
                    if key in seen:
                        continue
                    if self.row_cache.get(key, detail, p.names) is None:
                        seen.add(key)
                        fresh_keys.append(key)
                        fresh_rows.append(row)
        if sp is not None:
            sp.attrs["requests"] = len(pending)
            sp.attrs["fresh_rows"] = len(fresh_rows)
        rep, used_detail, exc = None, detail, None
        if fresh_rows:                         # dispatch without the lock
            rep, used_detail, exc = self._dispatch_degrading(
                np.stack(fresh_rows), detail)
        with self._lock:
            if rep is not None:
                self._c_fused.inc()
                for i, key in enumerate(fresh_keys):
                    self.row_cache.put(key, used_detail, rep.row(i))
            for p in pending:
                if self._try_resolve(p):
                    self._c_coalesced.inc()
                    continue
                # last rung: serve whatever detail the cache holds
                if ("cached" in self.degrade
                        and self._try_resolve_degraded(p)):
                    self._c_degraded.inc(rung="cached")
                    self._c_coalesced.inc()
                    continue
                if p.span is not None:
                    p.span.attrs["error"] = str(exc) if exc else "cache miss"
                    self.tracer.finish(p.span, status="error")
                p.future.set_exception(
                    exc if exc is not None else
                    RuntimeError("coalesced rows missing from cache"))
        return len(fresh_rows) if rep is not None else 0

    def _dispatch_degrading(self, rows: np.ndarray, detail: str):
        """One fused dispatch, degraded along the ladder on failure.

        Returns ``(report | None, detail actually evaluated, last error)``.
        """
        tr = self.tracer
        with tr.span("service.dispatch", rows=int(rows.shape[0]),
                     detail=detail) as sp:
            try:
                return (self.evaluator.evaluate(
                    EvalRequest(rows, detail=detail)), detail, None)
            except BaseException as exc:
                last: BaseException = exc
            if tr.enabled:
                sp.attrs["first_error"] = str(last)
            if "narrow" in self.degrade:
                # worker-loss recovery: halve the sharded pool and retry,
                # down to a single worker (the counter takes its own lock,
                # so concurrent self-ticking clients don't race here)
                while (getattr(self.evaluator, "workers", 1) > 1
                       and hasattr(self.evaluator, "resize")):
                    self.evaluator.resize(max(1, self.evaluator.workers // 2))
                    self._c_degraded.inc(rung="narrow")
                    try:
                        return (self.evaluator.evaluate(
                            EvalRequest(rows, detail=detail)), detail, None)
                    except BaseException as exc:
                        last = exc
            if "proxy" in self.degrade and detail != "objectives":
                try:
                    rep = self.evaluator.evaluate(
                        EvalRequest(rows, detail="objectives"))
                    self._c_degraded.inc(rung="proxy")
                    return rep, "objectives", None
                except BaseException as exc:
                    last = exc
            tr.finish(sp, status="error")
            return None, detail, last

    def _record_served(self, pend: _Pending) -> None:
        """Per-tier QoS accounting at resolve time (caller holds the
        lock): served count + queue-to-resolve latency sample."""
        self._c_tier_served.inc(tier=pend.tier)
        self._h_queue_lat.observe(self._clock() - pend.t_submit,
                                  tier=pend.tier)
        if pend.span is not None:
            self.tracer.finish(pend.span)

    def _try_resolve(self, pend: _Pending) -> bool:
        """Resolve a request from cache alone (caller holds the lock)."""
        rows: List[PPAReport] = []
        for row in pend.idx:
            ent = self.row_cache.get(RowCache.key(row), pend.detail,
                                     pend.names)
            if ent is None:
                return False
            rows.append(ent)
        pend.future.set_result(_assemble(rows, pend.names, pend.detail))
        self._record_served(pend)
        return True

    def _try_resolve_degraded(self, pend: _Pending) -> bool:
        """Resolve from cache at WHATEVER detail it holds (caller holds the
        lock): the response is demoted to the shallowest cached level of
        its rows — degraded service beats no service."""
        rows: List[PPAReport] = []
        floor = pend.detail
        for row in pend.idx:
            ent = self.row_cache.get_any(RowCache.key(row), pend.names)
            if ent is None:
                return False
            d, rep = ent
            if _DETAIL_LEVEL[d] < _DETAIL_LEVEL[floor]:
                floor = d
            rows.append(rep)
        pend.future.set_result(_assemble(rows, pend.names, floor))
        self._record_served(pend)
        return True

    def telemetry(self) -> dict:
        """Service + QoS + degradation counters (plus the evaluator's).

        A pure VIEW over the metrics registry — exact same keys as the
        pre-registry ad-hoc dicts (frozen by test)."""
        with self._lock:
            queued = {t: sum(len(q) for q in self._queues[t].values())
                      for t in QOS_TIERS}
        tiers = {}
        for t in QOS_TIERS:
            p50 = self._h_queue_lat.percentile(50, tier=t)
            p99 = self._h_queue_lat.percentile(99, tier=t)
            tiers[t] = {
                "weight": self.tier_weights[t],
                "served": int(self._c_tier_served.value(tier=t)),
                "queued": queued[t],
                "p50_ms": (round(p50 * 1e3, 3) if p50 is not None else None),
                "p99_ms": (round(p99 * 1e3, 3) if p99 is not None else None),
            }
        out = {
            "submits": self.submits,
            "cache_hits": self.cache_hits,
            "fused_dispatches": self.fused_dispatches,
            "coalesced_requests": self.coalesced_requests,
            "degraded": dict(self.degraded),
            "tiers": tiers,
        }
        for name in ("dispatches", "worker_dispatches", "retried",
                     "straggler_redispatches", "timeouts",
                     "corrupt_rejected", "resizes"):
            val = getattr(self.evaluator, name, None)
            if isinstance(val, int):
                out[f"evaluator_{name}"] = val
        return out

    # -- synchronous Evaluator facade ----------------------------------
    def evaluate(self, request: EvalRequest) -> PPAReport:
        """Submit + (self-)tick + result: the drop-in Evaluator call."""
        fut = self.submit(request)
        while not fut.done() and self._batcher is None:
            self.tick()                        # bounded ticks drain in turns
        return fut.result()

    def objectives(self, idx: np.ndarray) -> np.ndarray:
        return self.evaluate(EvalRequest(idx, detail="objectives")).objectives

    def ppa(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="ppa"))

    def stalls(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="stalls"))

    def __call__(self, idx: np.ndarray) -> np.ndarray:
        return self.objectives(idx)

    # -- lifecycle ------------------------------------------------------
    def _batch_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queued() and not self._closed:
                    self._cond.wait()
                if self._closed:
                    return
            time.sleep(self.window_s)          # the coalescing window
            self.tick()

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._batcher is not None:
            self._batcher.join(timeout=1.0)
        while self._queued():                  # drain any stragglers
            self.tick()

    def cache_clear(self) -> None:
        self.row_cache.clear()
