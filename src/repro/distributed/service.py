"""Async evaluation service: request queue + coalescing batcher + futures.

:class:`EvalService` generalizes :meth:`~repro.core.explore.
ExplorationEngine.prefetch` from "one runner batches its own candidates"
to "ANY concurrent clients coalesce": K campaigns, interleaved baseline
sweeps and benchmark probes all :meth:`~EvalService.submit` their
:class:`~repro.perfmodel.evaluator.EvalRequest`\\ s, and each
:meth:`~EvalService.tick` drains the queue into ONE fused dispatch on the
underlying evaluator — deduplicating design rows across clients and
resolving every request's future from the shared result.

* **Coalescing**: a tick evaluates the union of queued rows once, at the
  maximum detail level any queued request asked for (``objectives`` <
  ``ppa`` < ``stalls`` — latencies are bit-identical across levels, so
  higher detail only adds fields).
* **Shared cross-client cache**: every evaluated design row is cached
  (bounded LRU); a request whose rows are all cached at sufficient detail
  resolves at :meth:`~EvalService.submit` time with NO dispatch, whoever
  evaluated it first.
* **Evaluator protocol**: the service itself implements ``evaluate`` /
  ``objectives`` / ``workloads`` — hand it to ``CampaignRunner``,
  ``LuminaDSE``, a baseline driver or a bench wherever an ``Evaluator``
  is expected.  A synchronous ``evaluate`` call self-ticks when its rows
  are not already resolved.
* **Ticking**: call :meth:`tick` explicitly (deterministic — what the
  round-driven ``CampaignRunner`` does), or construct with
  ``autostart=True`` for a background batcher thread that ticks after a
  short coalescing window.

The underlying evaluator may itself be a :class:`~repro.distributed.
sharded.ShardedEvaluator`, composing "coalesce across clients" with
"shard across workers".
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.perfmodel.evaluator import (DETAILS, EvalRequest, PPAReport,
                                       as_evaluator)

_DETAIL_LEVEL = {name: i for i, name in enumerate(DETAILS)}


@dataclass
class _Pending:
    idx: np.ndarray                      # (n, n_params) int32
    detail: str
    names: Tuple[str, ...]
    future: Future


def _assemble(rows: List[PPAReport], names: Tuple[str, ...],
              detail: str) -> PPAReport:
    """Stack cached single-row reports into one response, restricted to the
    request's workloads and demoted to its detail level."""
    rep = PPAReport(
        workloads=names, detail=detail,
        area=np.concatenate([r.area for r in rows]),
        latency={nm: np.concatenate([r.latency[nm] for r in rows])
                 for nm in names})
    if detail in ("ppa", "stalls"):
        rep.op_time = {nm: np.concatenate([r.op_time[nm] for r in rows])
                       for nm in names}
        rep.op_names = {nm: rows[0].op_names[nm] for nm in names}
    if detail == "stalls":
        rep.stall = {nm: np.concatenate([r.stall[nm] for r in rows])
                     for nm in names}
        rep.op_class = {nm: np.concatenate([r.op_class[nm] for r in rows])
                        for nm in names}
    return rep


class EvalService:
    """Coalescing evaluation front-end over one (possibly sharded) evaluator.

    Parameters
    ----------
    evaluator:
        Anything :func:`~repro.perfmodel.evaluator.as_evaluator` accepts —
        typically a :class:`~repro.perfmodel.evaluator.ModelEvaluator` or a
        :class:`~repro.distributed.sharded.ShardedEvaluator`.
    cache_rows:
        Bound on the shared per-design report cache (LRU beyond it).
    autostart:
        Start a background batcher thread that ticks whenever requests sit
        in the queue longer than ``window_s`` (the coalescing window).
        Without it, call :meth:`tick` yourself — synchronous ``evaluate``
        calls also self-tick.
    """

    def __init__(self, evaluator, *, cache_rows: int = 65_536,
                 autostart: bool = False, window_s: float = 0.002):
        self.evaluator = as_evaluator(evaluator)
        self.space = self.evaluator.space
        self.tier = self.evaluator.tier
        self.cache_rows = int(cache_rows)
        self.window_s = float(window_s)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queue: List[_Pending] = []
        # design-row cache: key -> (detail level, 1-row PPAReport, all names)
        self._cache: "OrderedDict[bytes, Tuple[int, PPAReport]]" = OrderedDict()
        self._closed = False
        # traffic counters
        self.submits = 0                 # requests received
        self.cache_hits = 0              # requests resolved straight from cache
        self.fused_dispatches = 0        # ticks that reached the evaluator
        self.coalesced_requests = 0      # requests resolved by a fused tick
        self._batcher: Optional[threading.Thread] = None
        if autostart:
            self._batcher = threading.Thread(target=self._batch_loop,
                                             name="eval-service-batcher",
                                             daemon=True)
            self._batcher.start()

    # -- protocol surface ----------------------------------------------
    @property
    def workloads(self) -> Tuple[str, ...]:
        return self.evaluator.workloads

    @property
    def models(self):
        return self.evaluator.models

    @property
    def dispatches(self) -> int:
        """Fused device dispatches spent by the underlying evaluator."""
        return getattr(self.evaluator, "dispatches", 0)

    # -- async API ------------------------------------------------------
    def submit(self, request: EvalRequest) -> Future:
        """Enqueue one request; the returned future resolves to a PPAReport.

        Requests whose rows are ALL cached at sufficient detail resolve
        immediately (no queue, no dispatch) — the shared cross-client
        cache path.
        """
        idx = np.atleast_2d(np.asarray(request.idx, dtype=np.int32))
        names = (self.workloads if request.workloads is None
                 else tuple(request.workloads))
        unknown = set(names) - set(self.workloads)
        if unknown:
            raise KeyError(f"unknown workloads {sorted(unknown)}; "
                           f"have {self.workloads}")
        pend = _Pending(idx, request.detail, names, Future())
        with self._lock:
            if self._closed:
                raise RuntimeError("EvalService is closed")
            self.submits += 1
            if self._try_resolve(pend):
                self.cache_hits += 1
            else:
                self._queue.append(pend)
                self._cond.notify()
        return pend.future

    def tick(self) -> int:
        """Drain the queue into ONE fused dispatch; resolve every future.

        Returns the number of design rows actually dispatched (0 when the
        queue was empty or fully cache-resident).  The fused dispatch runs
        OUTSIDE the service lock, so concurrent clients keep submitting
        (their requests form the next tick's batch); an evaluator failure
        lands on the drained futures as an exception instead of orphaning
        them, so blocked ``result()`` callers — and the autostart batcher —
        always make progress.
        """
        with self._lock:
            pending, self._queue = self._queue, []
            if not pending:
                return 0
            level = max(_DETAIL_LEVEL[p.detail] for p in pending)
            detail = DETAILS[level]
            fresh_rows: List[np.ndarray] = []
            fresh_keys: List[bytes] = []
            seen: set = set()
            for p in pending:
                for row in p.idx:
                    key = row.tobytes()
                    if key in seen:
                        continue
                    ent = self._cache.get(key)
                    if ent is None or ent[0] < level:
                        seen.add(key)
                        fresh_keys.append(key)
                        fresh_rows.append(row)
        rep = None
        if fresh_rows:
            try:                               # dispatch without the lock
                rep = self.evaluator.evaluate(
                    EvalRequest(np.stack(fresh_rows), detail=detail))
            except BaseException as exc:
                for p in pending:
                    p.future.set_exception(exc)
                return 0
        with self._lock:
            if rep is not None:
                self.fused_dispatches += 1
                for i, key in enumerate(fresh_keys):
                    self._cache[key] = (level, rep.row(i))
                    self._cache.move_to_end(key)
            for p in pending:
                self.coalesced_requests += 1
                if not self._try_resolve(p):   # unreachable by construction
                    p.future.set_exception(
                        RuntimeError("coalesced rows missing from cache"))
            while len(self._cache) > self.cache_rows:
                self._cache.popitem(last=False)
        return len(fresh_rows)

    def _try_resolve(self, pend: _Pending) -> bool:
        """Resolve a request from cache alone (caller holds the lock)."""
        level = _DETAIL_LEVEL[pend.detail]
        rows: List[PPAReport] = []
        for row in pend.idx:
            ent = self._cache.get(row.tobytes())
            if ent is None or ent[0] < level:
                return False
            rows.append(ent[1])
        for row in pend.idx:                   # touch AFTER the full check
            self._cache.move_to_end(row.tobytes())
        pend.future.set_result(_assemble(rows, pend.names, pend.detail))
        return True

    # -- synchronous Evaluator facade ----------------------------------
    def evaluate(self, request: EvalRequest) -> PPAReport:
        """Submit + (self-)tick + result: the drop-in Evaluator call."""
        fut = self.submit(request)
        if not fut.done() and self._batcher is None:
            self.tick()
        return fut.result()

    def objectives(self, idx: np.ndarray) -> np.ndarray:
        return self.evaluate(EvalRequest(idx, detail="objectives")).objectives

    def ppa(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="ppa"))

    def stalls(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="stalls"))

    def __call__(self, idx: np.ndarray) -> np.ndarray:
        return self.objectives(idx)

    # -- lifecycle ------------------------------------------------------
    def _batch_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._closed:
                    self._cond.wait()
                if self._closed:
                    return
            time.sleep(self.window_s)          # the coalescing window
            self.tick()

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._batcher is not None:
            self._batcher.join(timeout=1.0)
        self.tick()                            # drain any stragglers

    def cache_clear(self) -> None:
        with self._lock:
            self._cache.clear()
