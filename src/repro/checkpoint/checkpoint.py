"""Sharded checkpointing with restore-time resharding and async save.

Layout: <dir>/step_<n>/
    manifest.json      — tree structure, shapes, dtypes
    arr_<i>.npy.zst    — one zstd-compressed npy per leaf

Restore accepts a *different* mesh/sharding than the save (elastic restart):
leaves are loaded to host and device_put with the new sharding.  Saves can
run on a background thread (AsyncCheckpointer) so the train loop never
blocks on I/O — the pytree is snapshotted to host memory synchronously
(cheap) and written asynchronously.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np

try:
    import zstandard as zstd
    _Z = True
except Exception:                                    # pragma: no cover
    _Z = False

import jax


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _write_leaf(path: str, arr: np.ndarray) -> None:
    import io
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    data = buf.getvalue()
    if _Z:
        data = zstd.ZstdCompressor(level=3).compress(data)
    with open(path, "wb") as f:
        f.write(data)


def _read_leaf(path: str) -> np.ndarray:
    import io
    with open(path, "rb") as f:
        data = f.read()
    if _Z:
        data = zstd.ZstdDecompressor().decompress(data)
    return np.load(io.BytesIO(data), allow_pickle=False)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Synchronous save. Returns the step directory."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(x) for x in leaves]
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
        if hasattr(treedef, "serialize_using_proto") else None,
        "n_leaves": len(host),
        "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in host],
        "zstd": _Z,
    }
    for i, a in enumerate(host):
        _write_leaf(os.path.join(tmp, f"arr_{i}.npy.zst"), a)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(out):
        shutil.rmtree(out)
    os.rename(tmp, out)
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of `like`; if `shardings` (a pytree of
    jax.sharding.Sharding) is given, leaves are placed with it — this is the
    elastic-restart resharding path (save mesh != restore mesh)."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), \
        f"leaf count mismatch: ckpt {manifest['n_leaves']} vs {len(leaves_like)}"
    host = [_read_leaf(os.path.join(src, f"arr_{i}.npy.zst"))
            for i in range(len(leaves_like))]
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(shardings)
        placed = [jax.device_put(a, s) for a, s in zip(host, sh_leaves)]
    else:
        placed = [jax.device_put(a) for a in host]
    return jax.tree_util.tree_unflatten(treedef, placed)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()                                   # one in flight at a time
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]        # sync device->host
        snap = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, snap)
                self._gc()
            except BaseException as e:                # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
