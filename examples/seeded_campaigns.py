"""Sweep-seeded multi-campaign DSE: K parallel Lumina campaigns started
from the full-space sweep's per-stall-class best designs, sharing one
budget and one fused batched dispatch per round, with per-step regret
telemetry against the exhaustive oracle front.

    PYTHONPATH=src python examples/seeded_campaigns.py --budget 20 \
        [--sweep-stop 200000] [--telemetry campaigns.json]
"""
import argparse

import numpy as np

from repro.core.campaign import CampaignRunner
from repro.perfmodel import ModelEvaluator, OracleEvaluator, get_evaluator
from repro.perfmodel.designspace import SPACE


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=20)
    ap.add_argument("--seeds-per-campaign", type=int, default=1)
    ap.add_argument("--sweep-stop", type=int, default=None,
                    help="sweep only ids [0, stop) (default: full 4.7M space)")
    ap.add_argument("--telemetry", default=None,
                    help="write the per-step regret/PHV JSON series here")
    args = ap.parse_args()

    ev = get_evaluator("proxy")
    oracle = OracleEvaluator(ev, stop=args.sweep_stop,
                             sweep_kwargs=dict(stall_topk=16,
                                               stall_rank="ref"))
    sweep = oracle.sweep_result()        # one sweep: seeds AND ground truth
    seeds = sweep.stall_seeds()
    print("sweep:", sweep.n_evaluated, "designs,",
          {k: len(v) for k, v in seeds.items()}, "seeds/class")

    # acquisition runs on its own proxy instance so the dispatch report
    # below counts only the budgeted fused dispatches
    runner = CampaignRunner(ev, proxy=ModelEvaluator(ev.models),
                            oracle=oracle, seed=0,
                            seeds_per_campaign=args.seeds_per_campaign)
    res = runner.run(budget=args.budget, sweep=sweep)

    print(f"\n{len(res.per_campaign)} campaigns, {len(res.samples)} evals in "
          f"{res.rounds} rounds / {res.dispatches} fused dispatches")
    print(f"merged: {res.superior_count} A100-superior designs, "
          f"PHV fraction of oracle {res.phv_frac_curve()[-1]:.3f}, "
          f"final regret {np.round(res.regret_curve()[-1], 3)}")
    for label, r in sorted(res.per_campaign.items()):
        print(f"  {label:16s} evals={len(r.samples):3d} "
              f"superior={r.superior_count:3d} phv={r.phv:.3g}")
    best = res.pareto[0]
    print("\nbest merged design:", dict(
        (k, int(v)) for k, v in SPACE.decode_np(best.idx).items()))
    if args.telemetry:
        res.save_telemetry(args.telemetry)
        print("telemetry ->", args.telemetry)


if __name__ == "__main__":
    main()
