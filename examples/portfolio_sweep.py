"""Portfolio sweep: score the design space against the whole workload zoo.

One streaming pass over a slice of the 4.7M-point space evaluates every
assigned architecture config (10 scenarios, 20 stacked workloads) at once:
per-scenario Pareto fronts + stall-class seeds, plus the robust front under
worst-case scalarization — then a bottleneck-seeded DSE campaign targets
ONE scenario's stall classes.

    PYTHONPATH=src python examples/portfolio_sweep.py
"""
from repro.core.campaign import CampaignRunner
from repro.perfmodel import get_evaluator
from repro.perfmodel.sweep import SweepEngine

STOP = 150_000          # slice of the 4,741,632-design space (demo scale)


def main() -> None:
    zoo = get_evaluator("proxy", suite="zoo")
    print(f"zoo suite: {len(zoo.scenarios)} scenarios, "
          f"{len(zoo.workloads)} stacked workloads")

    eng = SweepEngine(zoo, stall_topk=4, archive_capacity="auto")
    res = eng.run(0, STOP, progress=True)
    print(f"\nswept {res.n_evaluated:,} designs in {res.seconds:.1f}s "
          f"({res.points_per_sec:,.0f} ids/s, robust={res.robust!r})")
    print(f"robust front: {len(res.pareto_ids)} designs "
          f"({res.n_superior} beat the A100 on EVERY scenario)")
    for name in res.scenario_names:
        r = res.scenario(name)
        seeds = res.stall_seeds(scenario=name)
        classes = [c for c, v in seeds.items() if len(v)]
        print(f"  {name:24s} front={len(r.pareto_ids):4d} "
              f"superior={r.n_superior:4d} stall classes={classes}")

    # bottleneck-seeded campaigns for one scenario class
    scen = res.scenario_names[0]
    runner = CampaignRunner(zoo, proxy=zoo, scenario=scen, seed=0)
    out = runner.run(budget=12, seeds=res.stall_seeds(scenario=scen))
    print(f"\nscenario {scen!r} campaigns: {sorted(out.per_campaign)}")
    print(f"  {len(out.samples)} evaluations in {out.rounds} fused rounds "
          f"({out.dispatches} dispatches), PHV={out.phv:.3e}")


if __name__ == "__main__":
    main()
