"""Quickstart: run a 20-sample Lumina DSE campaign against the A100
reference and print the Pareto-optimal designs it finds.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.perfmodel import get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.core.loop import LuminaDSE


def main() -> None:
    # the paper's evaluation workload: one GPT-3 175B layer, TP=8,
    # batch 8, seq 2048 (TTFT) / 1024th output token (TPOT), FP16.
    # The high-fidelity target tier pays the budget; the roofline proxy
    # tier serves QualE/QuanE acquisition for free.
    dse = LuminaDSE(get_evaluator("target"), proxy=get_evaluator("proxy"),
                    seed=0)

    result = dse.run(budget=20)

    print(f"evaluations: {len(result.samples)}  "
          f"designs dominating the A100: {result.superior_count}  "
          f"PHV: {result.phv:.4g}")
    print("\nPareto front (vs A100 = 1.0):")
    ref = dse.ref_point
    for s in result.pareto:
        vals = SPACE.decode_np(s.idx)
        cfgstr = " ".join(f"{k}={int(v)}" for k, v in vals.items())
        print(f"  TTFT {s.ttft / ref[0]:.3f}  TPOT {s.tpot / ref[1]:.3f}  "
              f"Area {s.area / ref[2]:.3f}   [{cfgstr}]")
    if result.trajectory_notes:
        print("\nreflection notes (refinement loop):")
        for n in result.trajectory_notes[:5]:
            print("  " + n)


if __name__ == "__main__":
    main()
