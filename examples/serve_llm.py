"""Batched serving example: prefill + decode with KV/state caches for any
assigned architecture (attention, MoE, RWKV, hybrid, enc-dec all share the
same serve API).

    PYTHONPATH=src python examples/serve_llm.py --arch rwkv6-7b
"""
import argparse

from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU-scale; default uses smoke config)")
    args = ap.parse_args()
    r = serve(args.arch, args.batch, args.prompt_len, args.gen,
              smoke=not args.full)
    print(f"arch={args.arch} generated {r['tokens'].shape}")
    print(f"TTFT {r['ttft_s'] * 1e3:.1f} ms   TPOT {r['tpot_s'] * 1e3:.2f} ms")
    print("sample:", r["tokens"][0][:12])


if __name__ == "__main__":
    main()
