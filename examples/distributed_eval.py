"""Distributed evaluation: a 2-worker sharded sweep plus two concurrent
DSE campaign sets coalescing through ONE EvalService.

The sharded evaluator fans each EvalRequest's design batch across N
workers (bit-identical report); the sweep engine shards its id range the
same way; and the EvalService merges every client's concurrent requests
into one fused dispatch per tick with a shared cross-client report cache.

    PYTHONPATH=src python examples/distributed_eval.py \
        [--workers 2] [--budget 12] [--sweep-stop 400000] [--mode thread]
"""
import argparse

import numpy as np

from repro.core.campaign import CampaignRunner
from repro.distributed import EvalService, ShardedEvaluator
from repro.perfmodel import EvalRequest, ModelEvaluator, get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.sweep import SweepEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--mode", default="thread",
                    choices=["thread", "process", "device"])
    ap.add_argument("--budget", type=int, default=12)
    ap.add_argument("--sweep-stop", type=int, default=400_000,
                    help="sweep only ids [0, stop) (keeps the demo quick)")
    args = ap.parse_args()

    # ---- 1. sharded evaluator: one request, N workers, same report ----
    local = ModelEvaluator(get_evaluator("proxy").models)
    sharded = ShardedEvaluator(ModelEvaluator(get_evaluator("proxy").models),
                               workers=args.workers, mode=args.mode)
    batch = SPACE.sample(np.random.default_rng(0), 4_096)
    a = local.evaluate(EvalRequest(batch, detail="stalls"))
    b = sharded.evaluate(EvalRequest(batch, detail="stalls"))
    same = all(np.array_equal(a.latency[w], b.latency[w])
               for w in local.workloads) and np.array_equal(a.area, b.area)
    print(f"sharded x{args.workers} ({sharded.mode}): "
          f"{batch.shape[0]} designs, bit-identical={same}, "
          f"worker dispatches={sharded.worker_dispatches}")

    # ---- 2. the sweep shards its id range across the same worker count ----
    eng = SweepEngine(get_evaluator("proxy"), stall_topk=8, stall_rank="ref")
    sweep = eng.run(0, args.sweep_stop, workers=args.workers)
    print(f"sweep x{args.workers}: {sweep.n_evaluated:,} ids, "
          f"front={len(sweep.pareto_ids)}, "
          f"{sweep.points_per_sec:,.0f} ids/s, "
          f"superior-to-A100={sweep.n_superior:,}")

    # ---- 3. two campaign sets through ONE coalescing service ----
    service = EvalService(ModelEvaluator(get_evaluator("proxy").models))
    proxy = ModelEvaluator(get_evaluator("proxy").models)
    for policy in ("uniform", "adaptive"):
        runner = CampaignRunner(service, proxy=proxy, seed=0, policy=policy)
        res = runner.run(budget=args.budget, sweep=sweep)
        weights = ("" if res.budget_weights is None else
                   ", weights=" + "/".join(
                       f"{lb}:{w:.2f}"
                       for lb, w in sorted(res.budget_weights.items())))
        print(f"campaigns[{policy}]: {len(res.per_campaign)} campaigns, "
              f"{len(res.samples)} evals in {res.rounds} rounds / "
              f"{res.dispatches} fused dispatches{weights}")
    print(f"service: {service.submits} requests -> "
          f"{service.fused_dispatches} fused dispatches, "
          f"{service.cache_hits} cross-client cache hits")


if __name__ == "__main__":
    main()
