"""Compare every DSE method (paper Fig. 4) on a workload derived from one of
the ASSIGNED architectures — each arch config doubles as a Lumina workload.

    PYTHONPATH=src python examples/explore_design_space.py \
        --arch rwkv6-7b --budget 150
"""
import argparse

from repro.configs import get_arch
from repro.core.baselines import METHODS, run_method
from repro.core.loop import LuminaDSE
from repro.perfmodel import make_evaluator
from repro.perfmodel.designspace import SPACE, A100_REFERENCE
from repro.perfmodel.workload import from_arch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-7b")
    ap.add_argument("--budget", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=2048)
    ap.add_argument("--backend", default=None,
                    help="evaluator backend: roofline|pallas|auto")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    evaluator = make_evaluator({
        "ttft": from_arch(cfg, args.batch, args.seq, decode=False),
        "tpot": from_arch(cfg, args.batch, args.seq, decode=True),
    }, backend=args.backend)

    ref = evaluator.objectives(SPACE.encode_nearest(A100_REFERENCE)[None, :])[0]
    print(f"workload: {args.arch}  A100 point: "
          f"TTFT {ref[0] * 1e3:.2f}ms TPOT {ref[1] * 1e6:.0f}us "
          f"area {ref[2]:.0f}mm2\n")

    print(f"{'method':8s} {'PHV':>10s} {'sample-eff':>10s} {'superior':>9s}")
    for name, cls in METHODS.items():
        r = run_method(cls, evaluator, args.budget, ref, seed=0, batch=8)
        print(f"{name:8s} {r.phv:10.4g} {r.sample_efficiency:10.3f} "
              f"{r.superior_count:9d}")
    res = LuminaDSE(evaluator, seed=0).run(budget=args.budget)
    print(f"{'LUMINA':8s} {res.phv:10.4g} {res.sample_efficiency:10.3f} "
          f"{res.superior_count:9d}")
    best = res.pareto[0]
    print("\nbest Lumina design:", dict(
        (k, int(v)) for k, v in SPACE.decode_np(best.idx).items()))


if __name__ == "__main__":
    main()
