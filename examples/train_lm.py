"""End-to-end training driver: train a ~100M-param llama-family model for a
few hundred steps on the deterministic synthetic LM stream, with async
checkpointing and resume.

Full run (~100M params — heavy on CPU, the real target is the TPU mesh):
    PYTHONPATH=src python examples/train_lm.py --steps 300

CI-scale check (reduced width, same code path):
    PYTHONPATH=src python examples/train_lm.py --steps 60 --ci
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get_arch
from repro.launch.train import train
import repro.launch.train as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ci", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.ci:
        losses = train("llama3.2-1b", steps=args.steps, batch=8, seq=64,
                       smoke=True, ckpt_dir=args.ckpt_dir)
    else:
        # ~100M: llama3.2-1b narrowed (8 layers, d_model 768, vocab 32k)
        cfg = get_arch("llama3.2-1b")
        small = dataclasses.replace(
            cfg, name="llama-100m", n_layers=8, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000,
            tie_embeddings=True)
        from repro.configs import ARCHS
        ARCHS[small.name] = small
        losses = train(small.name, steps=args.steps, batch=8, seq=256,
                       smoke=False, ckpt_dir=args.ckpt_dir)
    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(start {np.mean(losses[:10]):.4f})")


if __name__ == "__main__":
    main()
