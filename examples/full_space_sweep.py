"""Sweep the ENTIRE 4.7M-point design space and report the exact number of
designs that dominate the NVIDIA A100 reference — the paper's ground-truth
oracle that black-box DSE methods can only sample.

    PYTHONPATH=src python examples/full_space_sweep.py
    PYTHONPATH=src python examples/full_space_sweep.py --stop 500000 \
        --checkpoint /tmp/sweep_ck --checkpoint-every 8
"""
import argparse

from repro.perfmodel import get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.sweep import SweepEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stop", type=int, default=None,
                    help="sweep only flat ids [0, STOP) instead of the full space")
    ap.add_argument("--chunk", type=int, default=131_072)
    ap.add_argument("--backend", default="roofline",
                    choices=["roofline", "pallas"])
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="chunks between checkpoint writes")
    ap.add_argument("--resume", default=None,
                    help="checkpoint file to resume a partial sweep from")
    ap.add_argument("--stall-topk", type=int, default=8,
                    help="per-stall-class seed designs to track (0 = off)")
    args = ap.parse_args()

    eng = SweepEngine(get_evaluator("proxy"), chunk_size=args.chunk,
                      backend=args.backend, stall_topk=args.stall_topk)
    ref = eng.ref_point
    print(f"design space: {SPACE.size:,} points "
          f"({' x '.join(str(len(c)) for c in SPACE.choices)})")
    print(f"A100 reference: TTFT {ref[0] * 1e3:.2f}ms  "
          f"TPOT {ref[1] * 1e6:.0f}us  area {ref[2]:.0f}mm2\n")

    res = eng.run(stop=args.stop, checkpoint_path=args.checkpoint,
                  checkpoint_every=args.checkpoint_every,
                  resume_from=args.resume, progress=True)

    print(f"\nswept {res.n_evaluated:,} designs in {res.seconds:.1f}s "
          f"({res.points_per_sec:,.0f} designs/sec)")
    print(f"designs strictly dominating the A100 in ALL objectives: "
          f"{res.n_superior:,} "
          f"({100.0 * res.n_superior / max(res.n_evaluated, 1):.3f}%)")
    print(f"exact Pareto front: {len(res.pareto_ids)} designs"
          + (" (archive truncated)" if res.archive_truncated else ""))

    if res.n_evaluated == 0:
        print("\n(empty range: nothing swept)")
        return
    names = ("ttft", "tpot", "area")
    units = (1e3, 1e6, 1.0)
    print("\nbest design per objective:")
    for o, (nm, u) in enumerate(zip(names, units)):
        idx = SPACE.flat_to_idx(int(res.topk_ids[o][0]))
        vals = {k: int(v) for k, v in SPACE.decode_np(idx).items()}
        print(f"  {nm:5s} {res.topk_val[o][0] * u:10.4g} "
              f"{'ms' if o == 0 else 'us' if o == 1 else 'mm2':3s}  {vals}")

    if args.stall_topk:
        print("\nbottleneck-analysis seeds (best TTFT per dominant stall):")
        for stall, seeds in res.stall_seeds().items():
            if not len(seeds):
                print(f"  {stall:16s} (none found)")
                continue
            vals = {k: int(v) for k, v in SPACE.decode_np(seeds[0]).items()}
            print(f"  {stall:16s} {len(seeds):2d} seeds, best: {vals}")


if __name__ == "__main__":
    main()
