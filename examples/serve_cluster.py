"""DSE-as-a-service: an authenticated 2-worker cluster behind the Gateway.

Spawns two ``repro.serve`` worker daemons on localhost sharing an HMAC
keyring, has them announce themselves to a membership registrar (no
static address list), points a socket-mode ShardedEvaluator at the live
membership view (bit-identical to in-process over the signed binary
codec), injects chaos (a crashed and a hung dispatch) to show the retry
path, then runs a bottleneck-seeded campaign THROUGH the
admission-controlled gateway — QoS-tiered coalescing, per-tenant
budgets, fleet telemetry down to the lease table — and finally SIGKILLs
a worker mid-service to show elastic survival (its lease ages out; the
pool disables the slot).

    PYTHONPATH=src python examples/serve_cluster.py [--budget 10]

In production the workers run on other machines
(``python -m repro.serve.worker --host 0.0.0.0 --port 9707
--key fleet=... --registrar gateway:9700``) and nothing below changes:
discovery is the registrar, trust is the keyring.
"""
import argparse
import json

import numpy as np

from repro.core.campaign import CampaignRunner
from repro.distributed import (EvalService, FaultEvent, FaultPlan,
                               ShardedEvaluator)
from repro.perfmodel import EvalRequest, ModelEvaluator, get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.serve import (Gateway, Keyring, MembershipView, Registrar,
                         WorkerOptions, start_worker_process)

KEYS = {"fleet": b"demo-cluster-secret"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=10)
    args = ap.parse_args()
    rng = np.random.default_rng(0)
    ring = Keyring(KEYS)

    # ---- 1. the fleet: registrar + two authenticated workers ---------
    view = MembershipView(ttl_s=2.0)
    registrar = Registrar(view, keyring=ring).start()
    opts = WorkerOptions(keys=KEYS, registrar=registrar.address,
                         announce_interval_s=0.2,
                         max_rows_per_dispatch=4_096)
    w1 = start_worker_process(options=opts)
    w2 = start_worker_process(options=opts)
    view.wait_for(2)
    print(f"fleet: {len(view)} workers under lease -> {view.live()}")

    # ---- 2. socket fabric: signed codec, bit-identical ---------------
    local = ModelEvaluator(get_evaluator("proxy").models)
    batch = SPACE.sample(rng, 512)
    remote = ShardedEvaluator(ModelEvaluator(get_evaluator("proxy").models),
                              mode="socket", membership=view,
                              keyring=ring, elastic=True)
    a = local.evaluate(EvalRequest(batch, detail="stalls"))
    b = remote.evaluate(EvalRequest(batch, detail="stalls"))
    same = all(np.array_equal(a.latency[w], b.latency[w])
               for w in a.workloads) and np.array_equal(a.area, b.area)
    print(f"socket x2 (HMAC codec): {batch.shape[0]} designs, "
          f"bit-identical={same}, "
          f"worker dispatches={remote.worker_dispatches}")

    # ---- 3. chaos over the wire: crash + hang, same report -----------
    plan = FaultPlan([FaultEvent(0, 0, "crash"), FaultEvent(1, 1, "hang")])
    chaos = ShardedEvaluator(ModelEvaluator(get_evaluator("proxy").models),
                             mode="socket", membership=view, keyring=ring,
                             fault_plan=plan, shard_timeout_s=1.0,
                             speculate=False)
    c = chaos.evaluate(EvalRequest(batch, detail="stalls"))
    same = all(np.array_equal(a.latency[w], c.latency[w])
               for w in a.workloads)
    print(f"chaos: crash+hang injected, retried={chaos.retried}, "
          f"bit-identical={same}, plan drained={len(plan) == 0}")
    chaos.close()

    # ---- 4. a campaign through the admission-controlled gateway ------
    service = EvalService(remote)
    gateway = Gateway(service, rows_per_window=5_000, max_queued_rows=512)
    proxy = ModelEvaluator(get_evaluator("proxy").models)
    runner = CampaignRunner(service, proxy=proxy, seed=0, policy="adaptive")
    seeds = {"memory_bw": SPACE.sample(rng, 2),
             "compute": SPACE.sample(rng, 2)}
    res = runner.run(budget=args.budget, seeds=seeds)
    print(f"campaigns via gateway fleet: {len(res.per_campaign)} campaigns, "
          f"{len(res.samples)} evals in {res.rounds} rounds, "
          f"weights={res.budget_weights}")
    leases = gateway.telemetry()["fleet"]["leases"]
    print(f"leases: {json.dumps(leases, indent=1, default=str)}")

    # ---- 5. SIGKILL a worker; its lease lapses, service survives -----
    w2.kill()
    view.wait_for(1)                      # (already true; TTL ages w2 out)
    fut = gateway.submit(EvalRequest(SPACE.sample(rng, 64)), tenant="demo")
    while not fut.done():
        gateway.tick()
    fut.result()
    tel = gateway.telemetry()
    print(f"post-kill: leases={sorted(tel['fleet']['leases'])}, "
          f"fleet live={tel['fleet']['live']}, "
          f"admitted={tel['admission']['admitted']}")
    print("telemetry:", json.dumps(
        {"tiers": tel["service"]["tiers"], "tenants": tel["tenants"]},
        indent=1, default=str))

    gateway.close()
    remote.close()
    if w1.alive():
        w1.kill()
    if w2.alive():
        w2.kill()
    registrar.close()


if __name__ == "__main__":
    main()
