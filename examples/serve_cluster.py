"""DSE-as-a-service: a 2-worker loopback cluster behind the Gateway.

Spawns two ``repro.serve`` worker daemons on localhost, points a
socket-mode ShardedEvaluator at the fleet (bit-identical to in-process),
injects chaos (a crashed and a hung dispatch) to show the retry path,
then runs a bottleneck-seeded campaign THROUGH the admission-controlled
gateway — QoS-tiered coalescing, per-tenant budgets, fleet telemetry —
and finally SIGKILLs a worker mid-service to show elastic survival.

    PYTHONPATH=src python examples/serve_cluster.py [--budget 10]

In production the workers run on other machines
(``python -m repro.serve.worker --host 0.0.0.0 --port 9707``) and the
addresses list names them; everything below is unchanged.
"""
import argparse
import json

import numpy as np

from repro.core.campaign import CampaignRunner
from repro.distributed import (EvalService, FaultEvent, FaultPlan,
                               ShardedEvaluator)
from repro.perfmodel import EvalRequest, ModelEvaluator, get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.serve import Gateway, start_worker_process


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=10)
    args = ap.parse_args()
    rng = np.random.default_rng(0)

    # ---- 1. the fleet: two loopback worker daemons -------------------
    w1 = start_worker_process()
    w2 = start_worker_process()
    print(f"fleet: workers at {w1.address} and {w2.address}")

    # ---- 2. socket fabric: bit-identical to in-process ---------------
    local = ModelEvaluator(get_evaluator("proxy").models)
    batch = SPACE.sample(rng, 512)
    remote = ShardedEvaluator(ModelEvaluator(get_evaluator("proxy").models),
                              mode="socket",
                              addresses=[w1.address, w2.address],
                              elastic=True)
    a = local.evaluate(EvalRequest(batch, detail="stalls"))
    b = remote.evaluate(EvalRequest(batch, detail="stalls"))
    same = all(np.array_equal(a.latency[w], b.latency[w])
               for w in a.workloads) and np.array_equal(a.area, b.area)
    print(f"socket x2: {batch.shape[0]} designs, bit-identical={same}, "
          f"worker dispatches={remote.worker_dispatches}")

    # ---- 3. chaos over the wire: crash + hang, same report -----------
    plan = FaultPlan([FaultEvent(0, 0, "crash"), FaultEvent(1, 1, "hang")])
    chaos = ShardedEvaluator(ModelEvaluator(get_evaluator("proxy").models),
                             mode="socket",
                             addresses=[w1.address, w2.address],
                             fault_plan=plan, shard_timeout_s=1.0,
                             speculate=False)
    c = chaos.evaluate(EvalRequest(batch, detail="stalls"))
    same = all(np.array_equal(a.latency[w], c.latency[w])
               for w in a.workloads)
    print(f"chaos: crash+hang injected, retried={chaos.retried}, "
          f"bit-identical={same}, plan drained={len(plan) == 0}")
    chaos.close()

    # ---- 4. a campaign through the admission-controlled gateway ------
    service = EvalService(remote)
    gateway = Gateway(service, rows_per_window=5_000, max_queued_rows=512)
    proxy = ModelEvaluator(get_evaluator("proxy").models)
    runner = CampaignRunner(service, proxy=proxy, seed=0, policy="adaptive")
    seeds = {"memory_bw": SPACE.sample(rng, 2),
             "compute": SPACE.sample(rng, 2)}
    res = runner.run(budget=args.budget, seeds=seeds)
    print(f"campaigns via gateway fleet: {len(res.per_campaign)} campaigns, "
          f"{len(res.samples)} evals in {res.rounds} rounds, "
          f"weights={res.budget_weights}")

    # ---- 5. SIGKILL a worker; the service keeps answering ------------
    w2.kill()
    fut = gateway.submit(EvalRequest(SPACE.sample(rng, 64)), tenant="demo")
    while not fut.done():
        gateway.tick()
    fut.result()
    tel = gateway.telemetry()
    print(f"post-kill: fleet live={tel['fleet']['live']}, "
          f"evictions={tel['fleet']['evictions']}, "
          f"admitted={tel['admission']['admitted']}")
    print("telemetry:", json.dumps(
        {"tiers": tel["service"]["tiers"], "tenants": tel["tenants"]},
        indent=1, default=str))

    gateway.close()
    remote.close()
    if w1.alive():
        w1.kill()
    if w2.alive():
        w2.kill()


if __name__ == "__main__":
    main()
