"""Observability end to end: one causal trace across client and workers.

Spawns two ``repro.serve`` worker daemons, attaches one
:class:`repro.obs.Tracer` to the whole dispatch path (Gateway ->
EvalService -> ShardedEvaluator -> SocketPool -> wire -> worker), runs a
request with a chaos crash injected and another after SIGKILLing a
worker, then prints the causal span tree, validates it structurally,
and writes a Perfetto/Chrome-traceable JSON plus a metrics snapshot.

    PYTHONPATH=src python examples/traced_service.py

Open ``traced_service.json`` at https://ui.perfetto.dev to see the
client spans and the adopted worker spans on separate process lanes,
re-parented into one tree per request.
"""
import json

import numpy as np

from repro.distributed import (EvalService, FaultEvent, FaultPlan,
                               ShardedEvaluator)
from repro.obs import (Tracer, completeness_errors, render_tree,
                       trace_events, validate_trace_events, write_trace)
from repro.perfmodel import EvalRequest, ModelEvaluator, get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.serve import Gateway, start_worker_process


def main() -> None:
    rng = np.random.default_rng(0)
    w1 = start_worker_process()
    w2 = start_worker_process()
    print(f"fleet: workers at {w1.address} and {w2.address}")

    # one tracer threads through every layer; workers get the trace
    # context on the wire and ship their spans back in the result frame
    tracer = Tracer(proc="client")
    sharded = ShardedEvaluator(
        ModelEvaluator(get_evaluator("proxy").models),
        mode="socket", addresses=[w1.address, w2.address],
        fault_plan=FaultPlan([FaultEvent(0, 0, "crash")]),
        elastic=True, speculate=False, tracer=tracer)
    gw = Gateway(EvalService(sharded, tracer=tracer), tracer=tracer)

    batch = SPACE.sample(rng, 256)
    gw.evaluate(EvalRequest(batch, detail="stalls"), tenant="demo")
    print("request 1 done (chaos crash on the first dispatch, retried)")
    w2.kill()
    # a FRESH batch (the coalescing cache would swallow a repeat)
    gw.evaluate(EvalRequest(SPACE.sample(rng, 256), detail="stalls"),
                tenant="demo")
    print("request 2 done (one worker SIGKILLed, fleet degraded to 1)")

    spans = tracer.spans()
    assert completeness_errors(spans) == [], "causal tree incomplete"
    assert validate_trace_events(trace_events(spans)) == []
    print(f"\ncausal tree ({len(spans)} spans; '!'=error, '?'=lost):")
    print(render_tree(spans))

    write_trace("traced_service.json", spans)
    print("Perfetto trace -> traced_service.json")

    # the same registry feeds the fleet dashboard and flat exports
    tel = gw.telemetry()
    print("\nfleet telemetry:", json.dumps(tel.get("fleet", {}), indent=2,
                                           default=str))
    gw.save_snapshot("traced_service_metrics.json")
    print("metrics snapshot -> traced_service_metrics.json "
          "(render: python -m repro.obs.report traced_service_metrics.json)")

    gw.close()
    for w in (w1, w2):
        if w.alive():
            w.kill()


if __name__ == "__main__":
    main()
