"""Secure-fabric smoke: the zero-trust serve stack under fire.

The CI acceptance gate for the hardened transport: two spawned worker
processes speaking the schema-restricted binary codec with HMAC frame
signing AND worker-side quotas, driven by a sharded evaluation stream
that absorbs — in one run —

* a **quota rejection** (one worker caps ``max_rows_per_dispatch`` below
  the shard size, so its shards reroute to the open worker instead of
  retrying against the refusal),
* a **SIGKILL mid-stream** (no goodbye; eviction -> elastic resize),

with the merged report **bit-identical** to the in-process evaluator
(``secure,smoke_bit_identical,1``) and zero authentication noise on the
happy path.  A tampered frame against a live keyed worker is then
verified to be rejected + counted, never evaluated
(``secure,tamper_rejected,1``).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.distributed import ShardedEvaluator, ShardPayload, concat_reports
from repro.distributed.sharded import _worker_spec
from repro.perfmodel import EvalRequest, ModelEvaluator, get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.serve import (Keyring, WorkerOptions, WorkerServer,
                         start_worker_process, wire)
from repro.serve import codec as codec


def _fresh(tier: str = "proxy") -> ModelEvaluator:
    return ModelEvaluator(get_evaluator(tier).models, tier=tier)


def _identical(a, b) -> bool:
    if not (np.array_equal(a.area, b.area) and a.workloads == b.workloads):
        return False
    for w in a.workloads:
        if not np.array_equal(a.latency[w], b.latency[w]):
            return False
        if a.detail == "stalls" and not np.array_equal(a.stall[w],
                                                       b.stall[w]):
            return False
    return True


KEYS = {"ci": b"ci-smoke-secret"}


def run(smoke: bool = False, full: bool = False) -> List[str]:
    lines: List[str] = []
    rng = np.random.default_rng(17)
    batch = SPACE.sample(rng, 128 if smoke else 512)
    req = EvalRequest(batch, detail="stalls")
    want = _fresh().evaluate(req)

    # ---- quota rejection + SIGKILL, bit-identical merge --------------
    # worker 1 refuses anything over 4 rows (below the ~6-row shards the
    # chunks split into); worker 2 takes the reroutes until it is
    # SIGKILLed, after which worker 3 absorbs the fleet
    quota = WorkerOptions(keys=KEYS, max_rows_per_dispatch=4)
    open_ = WorkerOptions(keys=KEYS)
    w1 = start_worker_process(options=quota)
    w2 = start_worker_process(options=open_)
    w3 = start_worker_process(options=open_)
    ev = None
    try:
        ev = ShardedEvaluator(_fresh(), mode="socket",
                              addresses=[w1.address, w2.address, w3.address],
                              keyring=Keyring(KEYS), elastic=True)
        chunks = np.array_split(batch, 8)
        parts = []
        for i, chunk in enumerate(chunks):
            if i == 3:
                w2.kill()                       # no goodbye, mid-stream
            parts.append(ev.evaluate(EvalRequest(chunk, detail="stalls")))
        merged = concat_reports(parts)
        ok = _identical(merged, want)
        lines.append(f"secure,smoke_bit_identical,{int(ok)}")
        assert ok, "secure-fabric merged report diverged from in-process"
        lines.append(f"secure,quota_rerouted,{ev.quota_rerouted}")
        assert ev.quota_rerouted >= 1, \
            "rows quota never exercised the reroute path"
        lines.append(f"secure,post_kill_evictions,"
                     f"{ev.registry.snapshot()['evictions']}")
    finally:
        if ev is not None:
            ev.close()
        for w in (w1, w2, w3):
            if w.alive():
                w.kill()

    # ---- tampered frame: rejected, counted, never evaluated ----------
    srv = WorkerServer(options=WorkerOptions(keys=KEYS))
    srv.start()
    try:
        ring = Keyring(KEYS)
        sock = wire.connect((srv.host, srv.port))
        ch = codec.Channel(sock, keyring=ring)
        ch.client_handshake()
        ch.send(wire.Hello(_worker_spec(_fresh())))
        assert isinstance(ch.recv(), wire.Ready)
        payload = ShardPayload(SPACE.sample(rng, 2), "objectives", None)
        frame = bytearray(codec.seal_frame(
            codec.encode_msg(wire.Dispatch(0, payload)), ring, seq=1,
            binding=ch.binding))
        frame[-1] ^= 0xFF
        wire.send_frame(sock, bytes(frame))
        reply = ch.recv()
        rejected = (isinstance(reply, wire.ErrorMsg)
                    and reply.code == "auth.tamper")
        sock.close()
        deadline = time.monotonic() + 10
        while srv.auth_rejected("tamper") < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        rejected = rejected and srv.auth_rejected("tamper") == 1 \
            and srv.dispatches_served == 0
        lines.append(f"secure,tamper_rejected,{int(rejected)}")
        assert rejected, "tampered frame was not rejected+counted"
    finally:
        srv.close()
    return lines


if __name__ == "__main__":
    for line in run(smoke=True):
        print(line)
