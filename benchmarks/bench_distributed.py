"""Distributed evaluation service benchmark: sharded workers + coalescing.

Measures the new `repro.distributed` layer end to end:

* `ShardedEvaluator` bit-identity vs the local fused path (both tiers)
  and its batch throughput relative to one in-process evaluator;
* the N-worker `SweepEngine.run(workers=N)` id-range sharding — the merged
  result must reproduce the single-process Pareto front / top-k /
  stall-seed tables EXACTLY;
* `EvalService` coalescing: K concurrent clients' requests fuse into ONE
  dispatch per tick, and a `CampaignRunner` driven through the service
  keeps the ~1-dispatch-per-round invariant WITHOUT owning the batching.

``smoke=True`` (CI) bounds every range for a sub-minute run.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.campaign import CampaignRunner
from repro.distributed import EvalService, ShardedEvaluator
from repro.perfmodel import EvalRequest, ModelEvaluator, get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.sweep import SweepEngine

_WORKERS = 2


def _identical(a, b) -> bool:
    if not (np.array_equal(a.area, b.area)
            and a.workloads == b.workloads):
        return False
    for w in a.workloads:
        if not np.array_equal(a.latency[w], b.latency[w]):
            return False
        if a.stall is not None and not np.array_equal(a.stall[w], b.stall[w]):
            return False
    return True


def run(smoke: bool = False, workers: int = _WORKERS) -> List[str]:
    lines: List[str] = []
    rng = np.random.default_rng(0)
    batch = SPACE.sample(rng, 2_048 if smoke else 16_384)

    # ---- sharded bit-identity + throughput (both tiers) ----
    for tier in ("proxy", "target"):
        base = ModelEvaluator(get_evaluator(tier).models, tier=tier)
        sharded = ShardedEvaluator(ModelEvaluator(get_evaluator(tier).models,
                                                  tier=tier), workers=workers)
        req = EvalRequest(batch, detail="stalls")
        local_rep = base.evaluate(req)
        shard_rep = sharded.evaluate(req)
        lines.append(f"distributed,sharded_identical_{tier},"
                     f"{int(_identical(shard_rep, local_rep))}")
        base.objectives(batch)                      # warm both paths
        sharded.objectives(batch)
        t0 = time.perf_counter()
        base.objectives(batch)
        t_local = time.perf_counter() - t0
        t0 = time.perf_counter()
        sharded.objectives(batch)
        t_shard = time.perf_counter() - t0
        lines.append(f"distributed,sharded_speedup_{tier},"
                     f"{t_local / max(t_shard, 1e-9):.2f}x")
        sharded.close()

    # ---- N-worker sweep: merged result == single-process result ----
    stop = 300_000 if smoke else 1_200_000
    eng = SweepEngine(get_evaluator("proxy"), chunk_size=65_536,
                      stall_topk=8, stall_rank="ref")
    single = eng.run(0, stop)
    t0 = time.perf_counter()
    multi = eng.run(0, stop, workers=workers)
    t_multi = time.perf_counter() - t0
    same_front = (np.array_equal(single.pareto_ids, multi.pareto_ids)
                  and np.array_equal(single.pareto_y, multi.pareto_y))
    same_topk = np.array_equal(single.topk_val, multi.topk_val)
    seeds_s, seeds_m = single.stall_seeds(), multi.stall_seeds()
    same_seeds = all(np.array_equal(seeds_s[k], seeds_m[k]) for k in seeds_s)
    lines.append(f"distributed,sweep_workers,{workers}")
    lines.append(f"distributed,sweep_front_identical,{int(same_front)}")
    lines.append(f"distributed,sweep_topk_identical,{int(same_topk)}")
    lines.append(f"distributed,sweep_stall_seeds_identical,{int(same_seeds)}")
    lines.append(f"distributed,sweep_worker_points_per_sec,"
                 f"{stop / max(t_multi, 1e-9):.0f}")

    # ---- service coalescing: K clients -> 1 fused dispatch per tick ----
    ev = ModelEvaluator(get_evaluator("proxy").models)
    svc = EvalService(ev)
    k_clients = 6
    d0 = ev.dispatches
    futs = [svc.submit(EvalRequest(SPACE.sample(rng, 4), detail="stalls"))
            for _ in range(k_clients)]
    svc.tick()
    for f in futs:
        f.result()
    lines.append(f"distributed,service_clients,{k_clients}")
    lines.append(f"distributed,service_dispatches_per_tick,"
                 f"{ev.dispatches - d0}")

    # ---- campaigns through the service: batching lives in the service ----
    proxy = ModelEvaluator(get_evaluator("proxy").models)
    runner = CampaignRunner(svc, proxy=proxy, seed=0)
    seeds = {"memory_bw": SPACE.sample(rng, 2),
             "tensor_compute": SPACE.sample(rng, 2)}
    budget = 12 if smoke else 20
    res = runner.run(budget=budget, seeds=seeds)
    k = len(res.per_campaign)
    lines.append(f"distributed,campaign_count,{k}")
    lines.append(f"distributed,campaign_rounds,{res.rounds}")
    lines.append(f"distributed,campaign_fused_dispatches,{res.dispatches}")
    lines.append(f"distributed,campaign_dispatch_invariant_ok,"
                 f"{int(res.dispatches <= res.rounds + k + 2)}")
    lines.append(f"distributed,service_cache_hits,{svc.cache_hits}")
    return lines


if __name__ == "__main__":
    print("\n".join(run(smoke=True)))
