"""Roofline analysis over the dry-run artifacts (assignment deliverable g).

Reads experiments/dryrun/*.json (baseline cells + L2/L4 shallow-depth cells)
and produces the per-(arch x shape) roofline table:

  * three terms in seconds (compute / memory / collective) for the v5e-like
    target (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI);
  * the dominant bottleneck;
  * MODEL_FLOPS (6*N*D train / 2*N*D inference, N = active non-embedding
    params) and the usefulness ratio MODEL_FLOPS / (chips x HLO_FLOPs);
  * a one-line "what would move the dominant term" note.

Depth correction: XLA cost_analysis counts while-loop (scan) bodies ONCE, so
per-layer costs are extracted from two shallow compiles (L=2, L=4) and
extrapolated linearly to the full depth — every number still originates from
a compiled artifact.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"single": 256, "multi": 512}


# ---------------------------------------------------------------- params
def model_param_counts(arch: str) -> Dict[str, float]:
    """N_total / N_active / embedding sizes, from the abstract param tree."""
    import jax
    from repro.configs import ARCHS
    from repro.models import build_model

    cfg = ARCHS[arch]
    params = jax.eval_shape(build_model(cfg).init, jax.random.key(0))
    total = active = embed = 0.0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        keys = [str(getattr(k, "key", k)) for k in path]
        n = float(np.prod(leaf.shape))
        total += n
        if "embed" in keys or "lm_head" in keys:
            embed += n
            continue
        if "moe" in keys and keys[-1] in ("w_gate", "w_up", "w_down") \
                and "shared" not in keys:
            active += n * cfg.top_k / max(cfg.n_experts, 1)
        else:
            active += n
    return {"total": total, "active": active, "embed": embed,
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "enc_ctx": cfg.enc_ctx}


def model_flops(arch: str, shape_name: str, counts: Dict[str, float]) -> float:
    """Global MODEL_FLOPS per step: 6*N*D (train) / 2*N*D (fwd), with the
    logits matmul added explicitly (N excludes embedding tables)."""
    from repro.configs import SHAPES
    shape = SHAPES[shape_name]
    n = counts["active"]
    if shape.mode == "decode":
        d_tokens = shape.global_batch                 # one new token per seq
    else:
        d_tokens = shape.global_batch * shape.seq_len
    fwd = 2.0 * n * d_tokens
    fwd += 2.0 * d_tokens * counts["d_model"] * counts["vocab"]   # logits
    if counts["enc_ctx"] and shape.mode != "decode":
        # crude: encoder params ~ half of N for whisper; already inside N
        pass
    return 3.0 * fwd if shape.mode == "train" else fwd


# ---------------------------------------------------------------- loading
def load_cells(d: str) -> Dict[str, dict]:
    out = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        rec = json.load(open(f))
        key = (rec["arch"], rec["shape"], rec["mesh"],
               rec.get("layers_override"))
        out[key] = rec
    return out


def scan_units(arch: str) -> int:
    from repro.configs import ARCHS
    cfg = ARCHS[arch]
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else cfg.n_layers


def corrected_costs(cells: Dict, arch: str, shape: str,
                    mesh: str = "single") -> Optional[dict]:
    base = cells.get((arch, shape, mesh, None))
    l2 = cells.get((arch, shape, mesh, 2))
    l4 = cells.get((arch, shape, mesh, 4))
    if not base or base["status"] != "OK":
        return base
    if not (l2 and l4 and l2["status"] == "OK" and l4["status"] == "OK"):
        # fall back to the (undercounted) base numbers, flagged
        return {**base, "depth_corrected": False}
    units = scan_units(arch)

    def extrap(f2: float, f4: float) -> float:
        per = (f4 - f2) / 2.0
        return max(f2 + per * (units - 2), 0.0)

    flops = extrap(l2["flops"], l4["flops"])
    nbytes = extrap(l2["bytes_accessed"], l4["bytes_accessed"])
    kinds = set(l2["collectives"]) | set(l4["collectives"])
    coll = {k: extrap(l2["collectives"].get(k, 0.0),
                      l4["collectives"].get(k, 0.0)) for k in kinds}
    return {**base, "depth_corrected": True, "flops": flops,
            "bytes_accessed": nbytes, "collectives": coll}


# ---------------------------------------------------------------- table
def build_table(d: str, mesh: str = "single"):
    from repro.configs import ARCHS, SHAPES
    cells = load_cells(d)
    rows = []
    counts_cache = {}
    for arch in ARCHS:
        counts_cache[arch] = model_param_counts(arch)
        for shape in SHAPES:
            rec = corrected_costs(cells, arch, shape, mesh)
            if rec is None:
                rows.append({"arch": arch, "shape": shape, "status": "MISSING"})
                continue
            if rec["status"] != "OK":
                rows.append({"arch": arch, "shape": shape,
                             "status": rec["status"],
                             "note": rec.get("reason", rec.get("error", ""))})
                continue
            coll_bytes = sum(rec["collectives"].values())
            compute_s = rec["flops"] / PEAK_FLOPS
            memory_s = rec["bytes_accessed"] / HBM_BW
            coll_s = coll_bytes / ICI_BW
            terms = {"compute": compute_s, "memory": memory_s,
                     "collective": coll_s}
            dom = max(terms, key=terms.get)
            mf = model_flops(arch, shape, counts_cache[arch])
            hlo_global = rec["flops"] * CHIPS[mesh]
            ratio = mf / hlo_global if hlo_global else float("nan")
            frac = compute_s / max(terms[dom], 1e-30)
            rows.append({
                "arch": arch, "shape": shape, "status": "OK",
                "compute_s": compute_s, "memory_s": memory_s,
                "collective_s": coll_s, "dominant": dom,
                "roofline_fraction": frac,
                "model_flops": mf, "hlo_flops_global": hlo_global,
                "useful_ratio": ratio,
                "depth_corrected": rec.get("depth_corrected", False),
                "temp_bytes": rec["memory"].get("temp_size_in_bytes", 0),
                "note": _note(dom, rec, frac),
            })
    return rows


def _note(dom: str, rec: dict, frac: float) -> str:
    if dom == "compute":
        return "compute-bound: gains need better MXU utilization or fewer recomputed FLOPs"
    if dom == "memory":
        return ("memory-bound: fuse/keep activations in VMEM, raise arithmetic "
                "intensity (bigger per-chip tiles, bf16 cache)")
    heavy = max(rec["collectives"], key=rec["collectives"].get) \
        if rec["collectives"] else "?"
    return (f"collective-bound ({heavy}): reshard to cut {heavy} volume or "
            "overlap it with compute")


def render_markdown(rows) -> str:
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "roofline-frac | MODEL/HLO | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "OK":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — | {r.get('note', '')} |")
            continue
        star = "" if r["depth_corrected"] else " (uncorrected)"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']}{star} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_ratio']:.2f} | {r['note']} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()
    rows = build_table(args.dir, args.mesh)
    print(render_markdown(rows))
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    ok = [r for r in rows if r["status"] == "OK"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"= {worst['roofline_fraction']:.3f} ({worst['dominant']})")


if __name__ == "__main__":
    main()
