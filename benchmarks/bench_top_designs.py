"""Paper Table 4: Lumina's Designs A/B vs the A100 reference, on the
calibrated compass model.  Reports normalized TTFT / TPOT / Area and the
TTFT/Area, TPOT/Area efficiency products next to the paper's values.
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.perfmodel import get_evaluator
from repro.perfmodel.designspace import (SPACE, A100_REFERENCE, DESIGN_A,
                                         DESIGN_B)
from repro.perfmodel.hardware import area_mm2

PAPER = {  # (ttft, tpot, area, ttft/area, tpot/area)
    "A": (0.717, 0.947, 0.772, 1.805, 1.770),
    "B": (0.592, 0.948, 0.952, 1.366, 1.107),
}


def _area(des) -> float:
    v = {k: jnp.asarray([float(des[k])]) for k in SPACE.names}
    return float(area_mm2(v)[0])


def run() -> List[str]:
    target = get_evaluator("target")
    vals = {}
    for tag, des in (("A100", A100_REFERENCE), ("A", DESIGN_A), ("B", DESIGN_B)):
        y = target.objectives(SPACE.encode_nearest(des))[0]
        # the paper quotes the *unsnapped* 40 MB-gbuf area for the designs
        vals[tag] = (float(y[0]), float(y[1]), _area(des))
    ref = vals["A100"]
    lines = []
    for tag in ("A", "B"):
        t, p, a = (vals[tag][i] / ref[i] for i in range(3))
        ta, pa = 1.0 / (t * a), 1.0 / (p * a)
        pt = PAPER[tag]
        lines.append(f"table4,design{tag}_ttft,{t:.3f} (paper {pt[0]})")
        lines.append(f"table4,design{tag}_tpot,{p:.3f} (paper {pt[1]})")
        lines.append(f"table4,design{tag}_area,{a:.3f} (paper {pt[2]})")
        lines.append(f"table4,design{tag}_ttft_per_area,{ta:.3f} (paper {pt[3]})")
        lines.append(f"table4,design{tag}_tpot_per_area,{pa:.3f} (paper {pt[4]})")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
