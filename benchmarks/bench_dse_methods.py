"""Paper Figures 4 & 5: PHV + sample efficiency of every DSE method on the
roofline (proxy) tier, multiple independent trials.

Paper headline: Lumina beats the best baseline by +32.9% PHV and 17.5x
sample efficiency, finding 421 superior designs in 1000 samples vs ACO's 24.

PHV is additionally reported *oracle-normalized*: as a fraction of the
exhaustive 4.7M-point sweep front's PHV (the ground truth no sampling method
can exceed), via the ``oracle`` evaluator tier.  Lumina's campaigns are also
instrumented per step (``LuminaDSE.run(step_callback=...)``): the mean
per-objective regret vs the true optima is reported at 25/50/100% of the
budget.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.baselines import METHODS, run_method
from repro.core.loop import LuminaDSE
from repro.perfmodel import get_evaluator
from repro.perfmodel.designspace import SPACE, A100_REFERENCE


def make_evaluator():
    """Process-wide memoized proxy-tier evaluator (shared with every other
    benchmark module via repro.perfmodel.evaluator.get_evaluator)."""
    return get_evaluator("proxy")


def run(budget: int = 300, trials: int = 3, quick: bool = False) -> List[str]:
    if quick:
        budget, trials = 150, 2
    evaluator = make_evaluator()
    oracle = get_evaluator("oracle")
    ref = evaluator.objectives(SPACE.encode_nearest(A100_REFERENCE)[None, :])[0]
    lines = []
    stats: Dict[str, list] = {}
    for name, cls in METHODS.items():
        phvs, effs, sups = [], [], []
        t0 = time.time()
        for trial in range(trials):
            r = run_method(cls, evaluator, budget, ref, seed=trial, batch=8)
            phvs.append(r.phv)
            effs.append(r.sample_efficiency)
            sups.append(r.superior_count)
        stats[name] = phvs
        lines.append(f"fig4,{name}_phv_mean,{np.mean(phvs):.5g}")
        lines.append(f"fig4,{name}_phv_frac_of_oracle,"
                     f"{oracle.normalized_phv(np.mean(phvs), ref):.4f}")
        lines.append(f"fig4,{name}_eff_mean,{np.mean(effs):.4f}")
        lines.append(f"fig5,{name}_phv_best_worst_ratio,"
                     f"{(max(phvs) / max(min(phvs), 1e-12)):.2f}")
        lines.append(f"fig6,{name}_superior_mean,{np.mean(sups):.1f}")

    phvs, effs, sups = [], [], []
    regret_curves = []
    for trial in range(trials):
        # per-step regret vs the oracle front (running best per objective)
        best = np.full(3, np.inf)
        curve = []

        def track(campaign, sample, _best=best, _curve=curve):
            np.minimum(_best, sample.objectives, out=_best)
            _curve.append(oracle.regret(_best[None, :]))

        res = LuminaDSE(evaluator, seed=trial).run(budget=budget,
                                                   step_callback=track)
        regret_curves.append(np.stack(curve))
        phvs.append(res.phv)
        effs.append(res.sample_efficiency)
        sups.append(res.superior_count)
    mean_regret = np.mean(np.stack(regret_curves), axis=0)  # (budget, 3)
    for frac in (0.25, 0.5, 1.0):
        i = max(0, int(round(frac * budget)) - 1)
        lines.append(f"fig4,LUMINA_regret_at_{int(frac * 100)}pct,"
                     + "|".join(f"{r:.4f}" for r in mean_regret[i]))
    lines.append(f"fig4,LUMINA_phv_mean,{np.mean(phvs):.5g}")
    lines.append(f"fig4,LUMINA_phv_frac_of_oracle,"
                 f"{oracle.normalized_phv(np.mean(phvs), ref):.4f}")
    lines.append(f"fig4,LUMINA_eff_mean,{np.mean(effs):.4f}")
    lines.append(f"fig5,LUMINA_phv_best_worst_ratio,"
                 f"{(max(phvs) / max(min(phvs), 1e-12)):.2f}")
    lines.append(f"fig6,LUMINA_superior_mean,{np.mean(sups):.1f}")
    lines.append(f"fig4,oracle_phv,{oracle.oracle_phv(ref):.5g}")

    best_base = max(np.mean(v) for v in stats.values())
    best_eff = max(float(l.split(",")[2]) for l in lines
                   if "_eff_mean" in l and "LUMINA" not in l)
    lines.append(f"fig4,phv_gain_vs_best_baseline,"
                 f"{(np.mean(phvs) / max(best_base, 1e-12) - 1) * 100:.1f}%")
    lines.append(f"fig4,eff_gain_vs_best_baseline,"
                 f"{np.mean(effs) / max(best_eff, 1e-9):.1f}x")
    return lines


if __name__ == "__main__":
    print("\n".join(run(quick=True)))
