"""Ablations (beyond-paper): which Lumina component buys what.

Five variants, 20-eval budget on the compass tier, 3 seeds:
  full            — QualE + QuanE + SE(enhanced) + TM reflection + refinement
  no-enhanced     — SE corrective rules off (RuleOracle(enhanced=False))
  noisy-llm       — 30% error-injected oracle (refinement must recover)
  no-proxy        — QuanE sensitivity runs on the expensive tier (the paper's
                    §3.2.2 fallback, costs budget-equivalent evals; here we
                    emulate by shrinking the exploration budget accordingly)
  no-refine       — refinement loop disabled (static AHK, like white-box DSE)
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.loop import LuminaDSE
from repro.core.llm import RuleOracle, DegradedOracle
from repro.core.refine import RefinementLoop
from repro.perfmodel import get_evaluator


class _NoRefine(RefinementLoop):
    def update(self, sens, tm, sample):
        return ""

    def maybe_reanchor(self, sens, tm, evaluator, step):
        return sens


def run(budget: int = 20, trials: int = 3) -> List[str]:
    target = get_evaluator("target")
    proxy_ev = get_evaluator("proxy")

    def campaign(seed, llm=None, refine=True, proxy=True, b=budget):
        dse = LuminaDSE(target,
                        proxy=proxy_ev if proxy else None,
                        llm=llm, seed=seed)
        if not refine:
            dse.refiner = _NoRefine()
        return dse.run(budget=b)

    variants = {
        "full": dict(),
        "no_enhanced": dict(llm=RuleOracle(enhanced=False)),
        "noisy_llm": dict(llm=DegradedOracle(0.3, seed=7)),
        "no_proxy": dict(proxy=False, b=max(budget - 4, 4)),
        "no_refine": dict(refine=False),
    }
    lines = []
    for name, kw in variants.items():
        sups, phvs = [], []
        for t in range(trials):
            r = campaign(t, **kw)
            sups.append(r.superior_count)
            phvs.append(r.phv)
        lines.append(f"ablation,{name}_superior_mean,{np.mean(sups):.1f}")
        lines.append(f"ablation,{name}_phv_mean,{np.mean(phvs):.4g}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
