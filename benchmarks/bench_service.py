"""DSE-as-a-service benchmark: tick latency, QoS tiers, transport overhead.

Measures the serving layer end to end:

* **tick latency vs K clients** — one coalescing ``EvalService.tick``
  over K concurrent single-request clients (the fused-dispatch scaling
  the campaign runner rides);
* **tier p50/p99 under mixed load** — an interactive flood plus batch and
  scavenger traffic through the weighted-deficit drain, with the
  telemetry percentiles reported and scavenger throughput ASSERTED > 0
  (the anti-starvation floor);
* **transport overhead** — the same batch through the in-process
  evaluator, a 2-process pool and a 2-worker loopback socket fleet, with
  per-transport overhead vs in-process reported;
* **kill-mid-sweep smoke** — a chunked evaluation stream over the socket
  fleet with one worker SIGKILLed between chunks; the merged report must
  be bit-identical to the in-process result (the CI acceptance gate:
  ``service,smoke_bit_identical,1``);
* **codec + auth overhead** — the same batch over the legacy pickle wire
  (``insecure=True`` both ends) vs the schema-restricted binary codec
  with HMAC frame signing; the signed path must stay within 15% of
  pickle (``service,codec_auth_within_15pct,1``).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.distributed import EvalService, ShardedEvaluator, concat_reports
from repro.perfmodel import EvalRequest, ModelEvaluator, get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.serve import (Gateway, Keyring, WorkerOptions,
                         start_worker_process)


def _fresh(tier: str = "proxy") -> ModelEvaluator:
    return ModelEvaluator(get_evaluator(tier).models, tier=tier)


def _identical(a, b) -> bool:
    if not (np.array_equal(a.area, b.area) and a.workloads == b.workloads):
        return False
    return all(np.array_equal(a.latency[w], b.latency[w])
               for w in a.workloads)


def _timed(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(smoke: bool = False, full: bool = False) -> List[str]:
    lines: List[str] = []
    rng = np.random.default_rng(0)
    repeats = 3 if smoke else 5

    # ---- tick latency vs K concurrent clients ------------------------
    rows = 4
    for k in (1, 4, 16):
        svc = EvalService(_fresh())
        # warm the jit paths on rows the timed pass never reuses
        svc.submit(EvalRequest(SPACE.sample(rng, rows)))
        svc.tick()
        idx = SPACE.sample(rng, k * rows)       # fresh rows: no cache help

        def one_round(k=k, svc=svc, idx=idx):
            futs = [svc.submit(EvalRequest(idx[i * rows:(i + 1) * rows]),
                               client=f"c{i}") for i in range(k)]
            svc.tick()
            assert all(f.done() for f in futs)

        t = _timed(one_round, 1)                # single shot: cold rows
        lines.append(f"service,tick_ms_k{k},{t * 1e3:.2f}")
        lines.append(f"service,fused_dispatches_k{k},{svc.fused_dispatches}")
        svc.close()

    # ---- tier p50/p99 under mixed load -------------------------------
    svc = EvalService(_fresh(), max_rows_per_tick=16)
    n_inter, n_batch, n_scav = (60, 20, 10) if smoke else (200, 60, 30)
    pool_idx = SPACE.sample(rng, n_inter + n_batch + n_scav)
    k = 0
    futs = []
    for tier, n in (("interactive", n_inter), ("batch", n_batch),
                    ("scavenger", n_scav)):
        for _ in range(n):
            futs.append(svc.submit(EvalRequest(pool_idx[k:k + 1]),
                                   client=tier, tier=tier))
            k += 1
    ticks = 0
    while not all(f.done() for f in futs):
        svc.tick()
        ticks += 1
        if ticks <= max(2, n_scav):
            # the anti-starvation floor: scavenger progress EVERY tick
            assert svc.tier_served["scavenger"] >= min(ticks, n_scav), \
                "scavenger tier starved under interactive flood"
    tiers = svc.telemetry()["tiers"]
    for t in ("interactive", "batch", "scavenger"):
        lines.append(f"service,{t}_served,{tiers[t]['served']}")
        lines.append(f"service,{t}_p50_ms,{tiers[t]['p50_ms']}")
        lines.append(f"service,{t}_p99_ms,{tiers[t]['p99_ms']}")
    lines.append(f"service,mixed_load_ticks,{ticks}")
    svc.close()

    # ---- transport overhead + kill-mid-sweep smoke -------------------
    batch = SPACE.sample(rng, 256 if smoke else 2_048)
    req = EvalRequest(batch, detail="stalls")
    local = _fresh()
    want = local.evaluate(req)
    t_local = _timed(lambda: local.evaluate(req), repeats)
    lines.append(f"service,inproc_ms,{t_local * 1e3:.1f}")

    proc = ShardedEvaluator(_fresh(), workers=2, mode="process")
    assert _identical(proc.evaluate(req), want)
    t_proc = _timed(lambda: proc.evaluate(req), repeats)
    proc.close()
    lines.append(f"service,process_ms,{t_proc * 1e3:.1f}")
    lines.append(f"service,process_overhead_pct,"
                 f"{100.0 * (t_proc - t_local) / max(t_local, 1e-9):.1f}")

    w1 = start_worker_process()
    w2 = start_worker_process()
    try:
        sock = ShardedEvaluator(_fresh(), mode="socket",
                                addresses=[w1.address, w2.address],
                                elastic=True)
        assert _identical(sock.evaluate(req), want)
        t_sock = _timed(lambda: sock.evaluate(req), repeats)
        lines.append(f"service,socket_ms,{t_sock * 1e3:.1f}")
        lines.append(f"service,socket_overhead_pct,"
                     f"{100.0 * (t_sock - t_local) / max(t_local, 1e-9):.1f}")

        # the CI acceptance gate: chunked sweep, one worker SIGKILLed
        # between chunks, merged result bit-identical to in-process
        chunks = np.array_split(batch, 8)
        parts = []
        for i, chunk in enumerate(chunks):
            if i == 2:
                w2.kill()                       # no goodbye, mid-sweep
            parts.append(sock.evaluate(EvalRequest(chunk, detail="stalls")))
        merged = concat_reports(parts)
        ok = _identical(merged, want)
        lines.append(f"service,smoke_bit_identical,{int(ok)}")
        assert ok, "post-kill merged report diverged from in-process"
        lines.append(f"service,post_kill_evictions,"
                     f"{sock.registry.snapshot()['evictions']}")
        sock.close()
    finally:
        for w in (w1, w2):
            if w.alive():
                w.kill()

    # ---- wire codec + auth overhead ----------------------------------
    # the PR 10 acceptance gate: the schema-restricted binary codec with
    # HMAC frame signing must stay within 15% of the legacy pickle wire
    # on the socket dispatch path
    keys = {"bench": b"bench-secret"}
    wp1 = start_worker_process(options=WorkerOptions(insecure=True))
    wp2 = start_worker_process(options=WorkerOptions(insecure=True))
    ws1 = start_worker_process(options=WorkerOptions(keys=keys))
    ws2 = start_worker_process(options=WorkerOptions(keys=keys))
    try:
        pick = ShardedEvaluator(_fresh(), mode="socket",
                                addresses=[wp1.address, wp2.address],
                                insecure=True)
        assert _identical(pick.evaluate(req), want)
        t_pick = _timed(lambda: pick.evaluate(req), repeats)
        pick.close()
        lines.append(f"service,socket_pickle_ms,{t_pick * 1e3:.1f}")

        sec = ShardedEvaluator(_fresh(), mode="socket",
                               addresses=[ws1.address, ws2.address],
                               keyring=Keyring(keys))
        assert _identical(sec.evaluate(req), want)
        t_sec = _timed(lambda: sec.evaluate(req), repeats)
        sec.close()
        lines.append(f"service,socket_codec_auth_ms,{t_sec * 1e3:.1f}")
        overhead = 100.0 * (t_sec - t_pick) / max(t_pick, 1e-9)
        lines.append(f"service,codec_auth_overhead_pct,{overhead:.1f}")
        lines.append(f"service,codec_auth_within_15pct,"
                     f"{int(overhead < 15.0)}")
    finally:
        for w in (wp1, wp2, ws1, ws2):
            if w.alive():
                w.kill()

    # ---- gateway admission sanity ------------------------------------
    gw = Gateway(_fresh(), rows_per_window=10_000, max_queued_rows=None)
    gw.objectives(SPACE.sample(rng, 8))
    tel = gw.telemetry()
    lines.append(f"service,gateway_admitted,{tel['admission']['admitted']}")
    lines.append(f"service,gateway_rejected,{tel['admission']['rejected']}")
    gw.close()
    return lines


if __name__ == "__main__":
    for line in run(smoke=True):
        print(line)
