"""Benchmark driver — one module per paper table/figure.

Prints ``name,metric,value[,derived]`` CSV lines.  Default scale is tuned
for CI (~10 min on this CPU container); pass --full for the paper-scale
suite (308-question benchmark, 1000-sample campaigns).

    PYTHONPATH=src python -m benchmarks.run [--full|--smoke] [--only table3,...]
"""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: truncate the sweep bench's throughput "
                         "pass to a 600k-id range, run fig4/5 at quick "
                         "scale, and drop budget20/ablation to one trial "
                         "(oracle-PHV normalization still sweeps the full "
                         "space once — a few seconds)")
    ap.add_argument("--only", default=None,
                    help="comma list: table3,fig45,fig6,budget20,table4,"
                         "sweep,campaigns,portfolio,distributed,faults,"
                         "service,secure,obs,kernels,archs,ablation")
    args = ap.parse_args()
    if args.full and args.smoke:
        raise SystemExit("--full and --smoke are mutually exclusive")
    only = set(args.only.split(",")) if args.only else None

    benches = []
    if only is None or "table3" in only:
        from benchmarks import bench_dse_benchmark
        benches.append(("table3",
                        lambda: bench_dse_benchmark.run(quick=not args.full)))
    if only is None or "fig45" in only:
        from benchmarks import bench_dse_methods
        benches.append(("fig4/5", lambda: bench_dse_methods.run(
            budget=1000 if args.full else 300,
            trials=5 if args.full else 3,
            quick=args.smoke)))
    if only is None or "fig6" in only:
        from benchmarks import bench_search_pattern
        benches.append(("fig6", bench_search_pattern.run))
    if only is None or "budget20" in only:
        from benchmarks import bench_budget20
        benches.append(("budget20", lambda: bench_budget20.run(
            trials=1 if args.smoke else 3)))
    if only is None or "table4" in only:
        from benchmarks import bench_top_designs
        benches.append(("table4", bench_top_designs.run))
    if only is None or "sweep" in only:
        from benchmarks import bench_sweep
        benches.append(("sweep", lambda: bench_sweep.run(full=args.full,
                                                         smoke=args.smoke)))
    if only is None or "campaigns" in only:
        from benchmarks import bench_campaigns
        benches.append(("campaigns",
                        lambda: bench_campaigns.run(smoke=args.smoke)))
    if only is None or "portfolio" in only:
        from benchmarks import bench_portfolio
        benches.append(("portfolio",
                        lambda: bench_portfolio.run(full=args.full,
                                                    smoke=args.smoke)))
    if only is None or "distributed" in only:
        from benchmarks import bench_distributed
        benches.append(("distributed",
                        lambda: bench_distributed.run(smoke=args.smoke)))
    if only is None or "faults" in only:
        from benchmarks import bench_faults
        benches.append(("faults",
                        lambda: bench_faults.run(smoke=args.smoke)))
    if only is None or "service" in only:
        from benchmarks import bench_service
        benches.append(("service",
                        lambda: bench_service.run(smoke=args.smoke,
                                                  full=args.full)))
    if only is None or "secure" in only:
        from benchmarks import bench_secure
        benches.append(("secure",
                        lambda: bench_secure.run(smoke=args.smoke,
                                                 full=args.full)))
    if only is None or "obs" in only:
        from benchmarks import bench_obs
        benches.append(("obs", lambda: bench_obs.run(smoke=args.smoke,
                                                     full=args.full)))
    if only is None or "kernels" in only:
        from benchmarks import bench_kernels
        benches.append(("kernels", bench_kernels.run))
    if only is None or "archs" in only:
        from benchmarks import bench_arch_workloads
        benches.append(("archs", bench_arch_workloads.run))
    if only is None or "ablation" in only:
        from benchmarks import bench_ablations
        benches.append(("ablation", lambda: bench_ablations.run(
            trials=3 if args.full else 1 if args.smoke else 2)))

    if only and not benches:
        raise SystemExit(f"no benchmark matches --only {args.only!r} "
                         "(see --help for valid names)")
    failures = 0
    for name, fn in benches:
        t0 = time.time()
        try:
            for line in fn():
                print(line, flush=True)
            print(f"meta,{name}_seconds,{time.time() - t0:.1f}", flush=True)
        except Exception:
            failures += 1
            print(f"meta,{name}_FAILED,1")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
