"""Paper Figure 6: search-pattern comparison (Lumina vs ACO).

Quantifies the "far-to-near" behaviour: mean normalized distance of each
evaluated design to the final best design, in thirds of the trajectory.
Lumina starts near (bottleneck-guided local moves from the reference); ACO
wanders before its pheromones concentrate.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.baselines import AntColony, run_method
from repro.core.loop import LuminaDSE
from repro.perfmodel import get_evaluator
from repro.perfmodel.designspace import SPACE, A100_REFERENCE


def _distance_profile(X: np.ndarray, Y: np.ndarray) -> List[float]:
    norm = (SPACE.cardinalities - 1)[None, :]
    best = X[int(np.argmin(Y.sum(axis=1)))]
    d = np.abs(X / norm - best[None, :] / norm).mean(axis=1)
    thirds = np.array_split(d, 3)
    return [float(t.mean()) for t in thirds]


def run(budget: int = 200) -> List[str]:
    evaluator = get_evaluator("proxy")

    ref = evaluator.objectives(SPACE.encode_nearest(A100_REFERENCE)[None, :])[0]
    aco = run_method(AntColony, evaluator, budget, ref, seed=0, batch=8)
    yn = aco.Y / ref[None, :]
    aco_prof = _distance_profile(aco.X, yn)

    res = LuminaDSE(evaluator, seed=0).run(budget=budget)
    X = np.stack([s.idx for s in res.samples])
    Y = np.stack([s.objectives for s in res.samples]) / ref[None, :]
    lum_prof = _distance_profile(X, Y)

    lines = [f"fig6,ACO_dist_thirds,{aco_prof[0]:.3f}/{aco_prof[1]:.3f}/{aco_prof[2]:.3f}",
             f"fig6,LUMINA_dist_thirds,{lum_prof[0]:.3f}/{lum_prof[1]:.3f}/{lum_prof[2]:.3f}",
             f"fig6,LUMINA_starts_nearer,{lum_prof[0] < aco_prof[0]}"]
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
