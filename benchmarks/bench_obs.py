"""Observability overhead benchmark + traced-fleet smoke.

Two claims, both ASSERTED (not just reported):

* **always-on-cheap** — the full dispatch tick path costs < 3% extra
  with a real :class:`~repro.obs.Tracer` attached vs the default
  :data:`~repro.obs.NOOP` tracer (``obs,traced_overhead_pct``), and the
  no-op span itself is sub-microsecond (``obs,noop_span_ns``);
* **one causal tree across machines** — a ``Gateway.evaluate`` against
  two SPAWNED worker processes, with a chaos crash injected on the
  first dispatch and one worker SIGKILLed between requests, still
  exports a schema-valid, structurally complete Perfetto trace (one
  root per trace, no dangling parents, every failed attempt closed
  ``error``/``lost``).  The trace JSON is written to
  ``obs_trace.json`` (override with ``REPRO_OBS_TRACE``) so CI can
  upload it as an artifact.
"""
from __future__ import annotations

import os
import time
from typing import List

import numpy as np

from repro.distributed import EvalService, ShardedEvaluator
from repro.distributed.faults import FaultEvent, FaultPlan
from repro.obs import (NOOP, Tracer, completeness_errors, trace_events,
                       validate_trace_events, write_trace)
from repro.perfmodel import EvalRequest, ModelEvaluator, get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.serve import Gateway, start_worker_process


def _fresh(tier: str = "proxy") -> ModelEvaluator:
    return ModelEvaluator(get_evaluator(tier).models, tier=tier)


def _timed(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(smoke: bool = False, full: bool = False) -> List[str]:
    lines: List[str] = []
    rng = np.random.default_rng(0)

    # ---- no-op span microbench ---------------------------------------
    n = 50_000 if smoke else 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with NOOP.span("x"):
            pass
    noop_ns = (time.perf_counter() - t0) / n * 1e9
    lines.append(f"obs,noop_span_ns,{noop_ns:.0f}")
    assert noop_ns < 5_000, f"no-op span costs {noop_ns:.0f}ns"

    # ---- tick-path overhead: traced vs NOOP --------------------------
    rows = 256 if smoke else 512
    repeats = 5 if smoke else 9
    req = EvalRequest(SPACE.sample(rng, rows), detail="stalls")

    base_svc = EvalService(_fresh())           # default tracer: NOOP
    base_svc.evaluate(req)                     # warm caches + compiles
    t_base = _timed(lambda: base_svc.evaluate(
        EvalRequest(SPACE.sample(rng, rows), detail="stalls")), repeats)
    base_svc.close()

    tr = Tracer(proc="bench")
    traced_svc = EvalService(_fresh(), tracer=tr)
    traced_svc.evaluate(req)
    t_traced = _timed(lambda: (
        traced_svc.evaluate(
            EvalRequest(SPACE.sample(rng, rows), detail="stalls")),
        tr.drain()), repeats)
    traced_svc.close()

    overhead = 100.0 * (t_traced - t_base) / max(t_base, 1e-9)
    lines.append(f"obs,tick_noop_ms,{t_base * 1e3:.2f}")
    lines.append(f"obs,tick_traced_ms,{t_traced * 1e3:.2f}")
    lines.append(f"obs,traced_overhead_pct,{overhead:.2f}")
    assert overhead < 3.0, f"tracing costs {overhead:.1f}% on the tick path"

    # ---- traced fleet smoke: chaos crash + SIGKILL, one tree ---------
    w1 = start_worker_process()
    w2 = start_worker_process()
    tr = Tracer(proc="client")
    try:
        plan = FaultPlan([FaultEvent(0, 0, "crash")])
        sock = ShardedEvaluator(_fresh(), mode="socket",
                                addresses=[w1.address, w2.address],
                                fault_plan=plan, elastic=True,
                                speculate=False, shard_timeout_s=10.0,
                                tracer=tr)
        gw = Gateway(EvalService(sock, tracer=tr), tracer=tr)
        batch = SPACE.sample(rng, 64 if smoke else 256)
        gw.evaluate(EvalRequest(batch, detail="stalls"), tenant="bench")
        w2.kill()                              # SIGKILL, no goodbye
        gw.evaluate(EvalRequest(batch, detail="stalls"), tenant="bench")

        spans = tr.spans()
        struct = completeness_errors(spans)
        assert struct == [], struct
        obj = trace_events(spans)
        schema = validate_trace_events(obj)
        assert schema == [], schema
        roots = [s for s in spans if s.parent_id is None]
        workers = {s.proc for s in spans if s.name == "worker.eval"}
        failed = [s for s in spans if s.status in ("error", "lost")]
        lines.append(f"obs,smoke_spans,{len(spans)}")
        lines.append(f"obs,smoke_traces,{len(roots)}")
        lines.append(f"obs,smoke_worker_procs,{len(workers)}")
        lines.append(f"obs,smoke_failed_attempts,{len(failed)}")
        assert len(roots) == 2                 # one tree per evaluate
        assert all(r.name == "gateway.evaluate" for r in roots)
        assert workers, "no worker spans crossed the wire"
        assert failed, "chaos + SIGKILL left no error/lost spans"

        out = os.environ.get("REPRO_OBS_TRACE", "obs_trace.json")
        write_trace(out, spans)
        lines.append(f"obs,trace_artifact,{out}")
        lines.append("obs,smoke_tree_complete,1")
        gw.close()
    finally:
        for w in (w1, w2):
            if w.alive():
                w.kill()
    return lines


if __name__ == "__main__":
    for line in run(smoke=True):
        print(line)
