"""Framework-specific table: every assigned architecture as a DSE workload.

For each of the 10 archs, evaluate the A100 reference point on the
arch-derived operator graph (prefill b8 s2048 / decode at kv 3072, TP=8,
mirroring the paper's GPT-3 setup) and report TTFT / TPOT / dominant stall.
This is the bridge between the model zoo and the Lumina core: any of these
rows can seed a DSE campaign (examples/explore_design_space.py).
"""
from __future__ import annotations

from typing import List

from repro.configs import ARCHS
from repro.perfmodel import make_evaluator
from repro.perfmodel.designspace import SPACE, A100_REFERENCE
from repro.perfmodel.workload import from_arch


def run() -> List[str]:
    idx = SPACE.encode_nearest(A100_REFERENCE)
    lines = []
    for name, cfg in ARCHS.items():
        ev = make_evaluator({
            "ttft": from_arch(cfg, batch=8, seq=2048, decode=False),
            "tpot": from_arch(cfg, batch=8, seq=2048, decode=True,
                              kv_len=3072),
        })
        reps = ev.stalls(idx).stall_reports()     # one fused dispatch/arch
        rt, rp = reps["ttft"], reps["tpot"]
        lines.append(f"archs,{name}_ttft_ms,{rt.latency * 1e3:.2f},"
                     f"stall={rt.dominant}")
        lines.append(f"archs,{name}_tpot_us,{rp.latency * 1e6:.1f},"
                     f"stall={rp.dominant}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
