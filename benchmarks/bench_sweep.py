"""Sweep-engine benchmark: the full 4,741,632-point space on one device.

The substrate headline (paper §4): vectorized evaluation turns 6000
CPU-hours / 1000 LLMCompass samples into seconds for the *whole* space.
Emits the evaluator-throughput trajectory (`points_per_sec`,
`full_sweep_seconds`), a brute-force cross-check of the streaming reduction
on a 50k-id subspace, and the per-stall-class seed designs (`stall_topk`)
that let bottleneck analysis start from sweep-discovered bottleneck regimes.

``smoke=True`` (CI) truncates the throughput sweep to a 600k-id range.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.pareto import dominates_ref, pareto_front
from repro.perfmodel import get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.sweep import SweepEngine


def run(full: bool = False, smoke: bool = False) -> List[str]:
    evaluator = get_evaluator("proxy")
    eng = SweepEngine(evaluator, stall_topk=8)
    lines = []

    # ---- correctness: streaming reduction vs brute force (--full: 4x ids) ----
    subspace = 200_000 if full else 50_000
    sub = eng.run(0, subspace)
    ys = evaluator.objectives(SPACE.flat_to_idx(np.arange(subspace)))
    front = pareto_front(ys)
    sup = int(dominates_ref(ys, eng.ref_point).sum())
    ok = (sub.n_superior == sup
          and len(sub.pareto_ids) == len(front)
          and np.allclose(np.sort(sub.pareto_y, axis=0),
                          np.sort(front, axis=0), rtol=1e-6))
    lines.append(f"sweep,subspace_check_ok,{int(ok)}")

    # ---- throughput: the full 4.7M-point sweep (600k ids in smoke mode) ----
    res = eng.run(0, 600_000 if smoke else None)
    lines.append(f"sweep,full_sweep_seconds,{res.seconds:.2f}")
    lines.append(f"sweep,points_per_sec,{res.points_per_sec:.0f}")
    lines.append(f"sweep,pareto_front_size,{len(res.pareto_ids)}")
    lines.append(f"sweep,superior_to_a100,{res.n_superior}")
    lines.append(f"sweep,archive_truncated,{int(res.archive_truncated)}")
    lines.append(f"sweep,best_ttft_s,{res.topk_val[0][0]:.6g}")
    lines.append(f"sweep,best_tpot_s,{res.topk_val[1][0]:.6g}")
    lines.append(f"sweep,best_area_mm2,{res.topk_val[2][0]:.5g}")
    for stall, seeds in res.stall_seeds().items():
        lines.append(f"sweep,stall_seeds_{stall},{len(seeds)}")

    # ---- chunk-size autotune: the timed probe picks the chunk for this
    # host (smoke probes smaller candidates to bound CI compile time) ----
    cands = (32_768, 65_536) if smoke else (65_536, 131_072, 262_144)
    auto = SweepEngine(evaluator, chunk_size="auto", chunk_candidates=cands,
                       stall_topk=8)
    lines.append(f"sweep,auto_chunk_size,{auto.chunk_size}")
    auto_res = auto.run(0, 600_000 if smoke else None)
    lines.append(f"sweep,auto_chunk_points_per_sec,"
                 f"{auto_res.points_per_sec:.0f}")

    # ---- archive-capacity sensitivity at --full scale: how small can the
    # bounded host archive get before the exact front starts truncating?
    # "auto" is the answer the study exists to validate: the data-derived
    # bound must reproduce the unbounded front without a user guess. ----
    if full:
        for cap in (1_024, 4_096, 16_384, "auto"):
            e2 = SweepEngine(evaluator, archive_capacity=cap)
            r2 = e2.run()
            lines.append(f"sweep,archive_cap_{cap}_front,{len(r2.pareto_ids)}")
            lines.append(f"sweep,archive_cap_{cap}_truncated,"
                         f"{int(r2.archive_truncated)}")
        lines.append(f"sweep,archive_cap_auto_sized,{r2.archive_capacity}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
