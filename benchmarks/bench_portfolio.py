"""Portfolio benchmark: the whole workload zoo in ~one-pass wall clock.

Three measurements behind the PR's acceptance bar:

* **W-scaling curve** — fused-dispatch latency of the stacked evaluator as
  the stacked workload count W grows (2 -> 20), against the looped
  per-workload path at the same W: the stacked path's cost is near-flat in
  W because the op-term model runs once over the deduped union
  (``dedup_*`` lines report the union-vs-concat op counts).
* **Portfolio sweep vs paper sweep** — the same id range swept with the
  2-workload paper evaluator and with the full zoo suite (10 scenarios,
  20 workloads, per-scenario fronts + stall seeds + robust front);
  ``zoo_vs_paper_ratio`` is the acceptance metric (must be <= 2x).
* **Robust vs per-scenario fronts** — how much the ``robust="worst"`` /
  ``"geomean"`` fronts overlap each scenario's own front, and how many
  designs beat the A100 on EVERY scenario at once (the robust superiority
  count) — the portfolio answer a per-workload sweep cannot give.

``smoke=True`` (CI) truncates the sweeps to a 200k-id range and thins the
W axis.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.perfmodel import (ModelEvaluator, get_evaluator, zoo_suite)
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.sweep import SweepEngine
from repro.perfmodel.workload import WorkloadStack


def _time_dispatch(ev, idx, repeats: int = 3) -> float:
    ev.objectives(idx)                       # compile + warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        ev.objectives(idx)
    return (time.perf_counter() - t0) / repeats


def run(full: bool = False, smoke: bool = False) -> List[str]:
    lines = []
    wls, scenarios = zoo_suite()
    stack = WorkloadStack.build(wls)
    lines.append(f"portfolio,zoo_workloads,{len(wls)}")
    lines.append(f"portfolio,zoo_ops_concat,{stack.total_ops}")
    lines.append(f"portfolio,zoo_ops_unique,{stack.n_unique}")

    # ---- W-scaling: stacked vs looped fused-dispatch latency ----
    names = list(wls)
    idx = SPACE.sample(np.random.default_rng(0), 4096)
    w_axis = (2, 8, 20) if smoke else (2, 4, 8, 12, 16, 20)
    base_ms = None
    for w in w_axis:
        sub = {nm: wls[nm] for nm in names[:w]}
        from repro.perfmodel.roofline import RooflineModel
        models = {nm: RooflineModel(wl) for nm, wl in sub.items()}
        ms_stacked = _time_dispatch(
            ModelEvaluator(models, stacked=True), idx) * 1e3
        ms_looped = _time_dispatch(
            ModelEvaluator(models, stacked=False), idx) * 1e3
        if base_ms is None:
            base_ms = ms_stacked
        lines.append(f"portfolio,stacked_w{w}_ms,{ms_stacked:.2f}")
        lines.append(f"portfolio,looped_w{w}_ms,{ms_looped:.2f}")
        lines.append(f"portfolio,stacked_w{w}_vs_w2,"
                     f"{ms_stacked / max(base_ms, 1e-9):.2f}")

    # ---- the acceptance sweep: zoo portfolio vs 2-workload paper ----
    stop = 200_000 if smoke else (None if full else 600_000)
    paper = SweepEngine(get_evaluator("proxy"), stall_topk=8)
    t0 = time.perf_counter()
    paper_res = paper.run(0, stop)
    paper_s = time.perf_counter() - t0
    lines.append(f"portfolio,paper_sweep_seconds,{paper_s:.2f}")
    lines.append(f"portfolio,paper_points_per_sec,"
                 f"{paper_res.points_per_sec:.0f}")

    zoo_ev = get_evaluator("proxy", suite="zoo")
    eng = SweepEngine(zoo_ev, stall_topk=4, archive_capacity="auto")
    t0 = time.perf_counter()
    res = eng.run(0, stop)
    zoo_s = time.perf_counter() - t0
    lines.append(f"portfolio,zoo_scenarios,{len(res.scenario_names)}")
    lines.append(f"portfolio,zoo_sweep_seconds,{zoo_s:.2f}")
    lines.append(f"portfolio,zoo_points_per_sec,{res.points_per_sec:.0f}")
    ratio = zoo_s / max(paper_s, 1e-9)
    lines.append(f"portfolio,zoo_vs_paper_ratio,{ratio:.2f}")
    lines.append(f"portfolio,zoo_vs_paper_ratio_ok,{int(ratio <= 2.0)}")
    lines.append(f"portfolio,robust_front_size,{len(res.pareto_ids)}")
    lines.append(f"portfolio,robust_superior_to_a100,{res.n_superior}")
    lines.append(f"portfolio,auto_archive_capacity,{res.archive_capacity}")

    # ---- the one-pass claim: vs S sequential per-scenario pair sweeps
    # (what scoring the zoo costs WITHOUT the portfolio engine; smoke
    # samples 3 scenarios and extrapolates to keep CI short) ----
    from repro.perfmodel import pair_view
    seq_scen = res.scenario_names[:3] if smoke else res.scenario_names
    seq_s = 0.0
    for s in zoo_ev.scenarios:
        if s.name not in seq_scen:
            continue
        pev = pair_view(zoo_ev, (s.prefill, s.decode))
        t0 = time.perf_counter()
        SweepEngine(pev, stall_topk=4).run(0, stop)
        seq_s += time.perf_counter() - t0
    seq_s *= len(res.scenario_names) / len(seq_scen)
    lines.append(f"portfolio,sequential_pair_sweeps_seconds,{seq_s:.2f}")
    lines.append(f"portfolio,zoo_vs_sequential_ratio,"
                 f"{zoo_s / max(seq_s, 1e-9):.2f}")

    # ---- robust vs per-scenario fronts ----
    robust_ids = set(int(i) for i in res.pareto_ids)
    for nm in res.scenario_names:
        r = res.scenario(nm)
        overlap = len(robust_ids & set(int(i) for i in r.pareto_ids))
        lines.append(f"portfolio,front_{nm},{len(r.pareto_ids)}")
        lines.append(f"portfolio,front_{nm}_robust_overlap,{overlap}")
        lines.append(f"portfolio,superior_{nm},{r.n_superior}")
        seeds = res.stall_seeds(scenario=nm)
        nonempty = sum(1 for v in seeds.values() if len(v))
        lines.append(f"portfolio,stall_classes_{nm},{nonempty}")

    # worst-case vs geometric-mean scalarization of the same space slice
    geo = SweepEngine(zoo_ev, robust="geomean",
                      archive_capacity="auto").run(0, stop)
    shared = len(robust_ids & set(int(i) for i in geo.pareto_ids))
    lines.append(f"portfolio,geomean_front_size,{len(geo.pareto_ids)}")
    lines.append(f"portfolio,geomean_worst_overlap,{shared}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
