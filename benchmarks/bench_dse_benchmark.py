"""Paper Table 3: DSE-benchmark accuracy across backends.

Full-scale suite: 308 bottleneck / 127 prediction / 30 tuning questions.
Backends: the rule oracle with/without the corrective rules (the
"Enhanced"/"Original" axis) and degraded oracles emulating the paper's
weaker open-source models.
"""
from __future__ import annotations

import time

from repro.core.bench import generate_suite, accuracy_table
from repro.core.llm import RuleOracle, DegradedOracle

# paper Table 3 values for side-by-side reporting
PAPER = {
    ("Bottleneck Analysis", "qwen3"): (0.73, 0.80),
    ("Perf/Area Prediction", "qwen3"): (0.59, 0.82),
    ("Parameter Tuning", "qwen3"): (0.40, 0.63),
    ("Bottleneck Analysis", "phi4"): (0.70, 0.76),
    ("Perf/Area Prediction", "phi4"): (0.42, 0.61),
    ("Parameter Tuning", "phi4"): (0.30, 0.48),
    ("Bottleneck Analysis", "llama31"): (0.47, 0.53),
    ("Perf/Area Prediction", "llama31"): (0.23, 0.39),
    ("Parameter Tuning", "llama31"): (0.26, 0.46),
}


def run(n_bottleneck: int = 308, n_prediction: int = 127, n_tuning: int = 30,
        quick: bool = False):
    if quick:
        n_bottleneck, n_prediction, n_tuning = 80, 40, 20
    t0 = time.time()
    suite = generate_suite(n_bottleneck, n_prediction, n_tuning)
    backends = [
        RuleOracle(enhanced=True),           # plays "Qwen-3 (Enhanced)"
        RuleOracle(enhanced=False),          # plays "Qwen-3 (Original)"
        DegradedOracle(0.18, seed=0, enhanced=True, name="qwen3-proxy"),
        DegradedOracle(0.30, seed=1, enhanced=True, name="phi4-proxy"),
        DegradedOracle(0.50, seed=2, enhanced=False, name="llama31-proxy"),
    ]
    rows = accuracy_table(backends, suite)
    lines = []
    for task, name, acc in rows:
        lines.append(f"table3,{task}/{name},{acc:.3f}")
    lines.append(f"table3,suite_gen_seconds,{time.time() - t0:.1f}")
    return lines


if __name__ == "__main__":
    print("\n".join(run(quick=True)))
