"""Sweep-seeded multi-campaign DSE vs the single A100-start trajectory.

The paper's headline rests on bottleneck-guided starts; this bench measures
them directly: K parallel Lumina campaigns seeded from the sweep's
per-stall-class best designs (minimax-vs-reference ranking) against one
A100-start campaign, at the SAME shared budget, with

* per-step regret (per objective, vs the exhaustive oracle front) and
  PHV-fraction curves — persisted as a JSON time series;
* the fused-dispatch counter: K campaigns cost ~1 batched dispatch per
  round, not K (the acceptance invariant: dispatches << budget);
* the scheduling-policy ablation: ``policy="adaptive"`` (budget
  reallocation toward falling-regret campaigns + early-stop of stalled
  ones) vs the ``"uniform"`` round-robin, at the same budget;
* the ``seeds_per_campaign`` axis: do multi-seed step-0 lists beat
  spending those evaluations on more search steps at equal budget?
* the AHK-provenance ablation: campaigns driven by the SOURCE-EXTRACTED
  primary edges (``repro.analysis.influence``, the default) vs the frozen
  legacy hand-coded table they replaced — PHV and final regret at the
  same budget must match, since extraction proved equivalent.
"""
from __future__ import annotations

import os
import tempfile
from typing import List, Optional, Tuple

import numpy as np

from repro.core.campaign import CampaignRunner
from repro.perfmodel import ModelEvaluator, OracleEvaluator, get_evaluator

# smoke sweeps a 600k-id subrange (matches the sweep bench's smoke scale);
# the full run sweeps all 4.7M ids — a few seconds on one CPU device
_SMOKE_STOP = 600_000

# the hand-coded AHK table this repo used before repro.analysis extracted
# the same edges from the perfmodel source — frozen HERE only, as the
# historical reference arm of the provenance ablation
_LEGACY_PRIMARY = {
    "tensor_compute": "sa_dim",
    "vector_compute": "vector_width",
    "memory_bw": "mem_channels",
    "interconnect": "link_count",
}


def run(budget: int = 20, smoke: bool = False,
        telemetry_dir: Optional[str] = None,
        seeds_axis: Optional[Tuple[int, ...]] = None) -> List[str]:
    ev = get_evaluator("proxy")
    oracle = OracleEvaluator(ev, stop=_SMOKE_STOP if smoke else None,
                             sweep_kwargs=dict(stall_topk=16,
                                               stall_rank="ref"))
    sweep = oracle.sweep_result()        # one sweep: seeds AND ground truth
    seeds = sweep.stall_seeds()

    lines = [f"campaigns,seed_classes,"
             f"{sum(1 for v in seeds.values() if len(v))}"]

    # acquisition (QualE/QuanE) is proxy-tier and unbudgeted: give it its
    # own evaluator instance (same models + jit cache, separate dispatch
    # counter) so the reported dispatches are the budgeted ones only
    proxy = ModelEvaluator(ev.models)

    results = {}
    for name, use_seeds in (("seeded", True), ("a100", False)):
        runner = CampaignRunner(ev, proxy=proxy, oracle=oracle, seed=0)
        d0 = ev.dispatches
        res = runner.run(budget=budget, sweep=sweep if use_seeds else None)
        results[name] = res
        regret = res.regret_curve()
        phv_frac = res.phv_frac_curve()
        lines.append(f"campaigns,{name}_campaign_count,{len(res.per_campaign)}")
        lines.append(f"campaigns,{name}_superior,{res.superior_count}")
        lines.append(f"campaigns,{name}_phv_frac_final,{phv_frac[-1]:.4f}")
        lines.append(f"campaigns,{name}_rounds,{res.rounds}")
        lines.append(f"campaigns,{name}_fused_dispatches,{res.dispatches}")
        lines.append(f"campaigns,{name}_total_dispatches,{ev.dispatches - d0}")
        # curve checkpoints at 25/50/75/100% of budget
        for frac in (0.25, 0.5, 0.75, 1.0):
            i = min(len(regret) - 1, max(0, int(round(frac * budget)) - 1))
            lines.append(f"campaigns,{name}_phv_frac_at_{int(frac * 100)}pct,"
                         f"{phv_frac[i]:.4f}")
            lines.append(f"campaigns,{name}_regret_at_{int(frac * 100)}pct,"
                         + "|".join(f"{r:.4f}" for r in regret[i]))
        out_dir = telemetry_dir or tempfile.gettempdir()
        path = os.path.join(out_dir, f"lumina_campaigns_{name}.json")
        res.save_telemetry(path)
        lines.append(f"campaigns,{name}_telemetry_json,{path}")

    # the acceptance comparison: stall-seeded starts vs the A100 start
    lines.append(f"campaigns,seeded_ge_a100_phv,"
                 f"{int(results['seeded'].phv >= results['a100'].phv)}")
    lines.append(f"campaigns,seeded_phv_gain,"
                 f"{results['seeded'].phv / max(results['a100'].phv, 1e-300):.2f}x")

    # ---- scheduling-policy ablation: adaptive vs uniform, same budget ----
    # (the "seeded" run above IS policy="uniform")
    adaptive = CampaignRunner(ev, proxy=proxy, oracle=oracle, seed=0,
                              policy="adaptive").run(budget=budget,
                                                     sweep=sweep)
    lines.append(f"campaigns,adaptive_phv_frac_final,"
                 f"{adaptive.phv_frac_curve()[-1]:.4f}")
    lines.append(f"campaigns,adaptive_rounds,{adaptive.rounds}")
    bw = adaptive.budget_weights or {}
    lines.append(f"campaigns,adaptive_weight_min,"
                 f"{min(bw.values(), default=0):.3f}")
    lines.append(f"campaigns,adaptive_weight_max,"
                 f"{max(bw.values(), default=0):.3f}")
    lines.append(f"campaigns,adaptive_fused_dispatches,{adaptive.dispatches}")
    lines.append(f"campaigns,adaptive_vs_uniform_phv,"
                 f"{adaptive.phv / max(results['seeded'].phv, 1e-300):.3f}x")

    # ---- AHK-provenance ablation: extracted rules vs the legacy table ----
    # the "seeded" run above uses the source-extracted primaries (default);
    # this arm injects the frozen hand-coded table at the same budget/seed
    from repro.analysis.influence import primary_resources
    legacy = CampaignRunner(ev, proxy=proxy, oracle=oracle, seed=0,
                            primary_map=_LEGACY_PRIMARY).run(budget=budget,
                                                             sweep=sweep)
    lines.append(f"campaigns,extracted_eq_legacy_tables,"
                 f"{int(primary_resources() == _LEGACY_PRIMARY)}")
    lines.append(f"campaigns,legacy_table_phv_frac_final,"
                 f"{legacy.phv_frac_curve()[-1]:.4f}")
    lines.append(f"campaigns,legacy_table_regret_final,"
                 + "|".join(f"{r:.4f}" for r in legacy.regret_curve()[-1]))
    lines.append(f"campaigns,extracted_vs_legacy_phv,"
                 f"{results['seeded'].phv / max(legacy.phv, 1e-300):.3f}x")
    lines.append(f"campaigns,extracted_eq_legacy_phv,"
                 f"{int(abs(results['seeded'].phv - legacy.phv) < 1e-12)}")
    hist = results["seeded"].stall_histogram or {}
    lines.append("campaigns,seeded_stall_histogram,"
                 + "|".join(f"{k}:{v}" for k, v in sorted(hist.items())))
    audit = (results["seeded"].rule_audit or {}).get("counts", {})
    lines.append(f"campaigns,rule_audit_metric_agree,"
                 f"{audit.get('metric_agree', 0)}")
    lines.append(f"campaigns,rule_audit_probe_only,"
                 f"{audit.get('metric_probe_only', 0)}")

    # ---- seeds_per_campaign axis: multi-seed step-0 vs more SE steps ----
    if seeds_axis is None:
        seeds_axis = (1, 2) if smoke else (1, 2, 3)
    for spc in seeds_axis:
        r = CampaignRunner(ev, proxy=proxy, oracle=oracle, seed=0,
                           seeds_per_campaign=spc).run(budget=budget,
                                                       sweep=sweep)
        lines.append(f"campaigns,seeds{spc}_phv_frac_final,"
                     f"{r.phv_frac_curve()[-1]:.4f}")
        lines.append(f"campaigns,seeds{spc}_superior,{r.superior_count}")
        lines.append(f"campaigns,seeds{spc}_campaign_count,"
                     f"{len(r.per_campaign)}")
    return lines


if __name__ == "__main__":
    print("\n".join(run(smoke=True)))
