"""Fault-tolerance benchmark: chaos overhead, recovery latency, degradation.

Measures the always-on evaluation stack's failure behaviour:

* **chaos-off overhead** — the fault machinery (ChaosPool wrapper with an
  empty plan, receiver-side validation, heartbeat registry) vs the plain
  sharded path on the same batch; target < 2% wall-clock overhead;
* **recovery latency vs fault rate** — seeded crash/slow/corrupt plans at
  increasing rates; every run must stay bit-identical to the fault-free
  report while wall clock grows only with the injected fault traffic;
* **degradation-ladder hit rates** — an EvalService walked down each rung
  (narrow -> proxy -> cached -> deadline) with the rung traffic counters
  reported, and ZERO unhandled exceptions surfaced to clients;
* **chaos sweep** — a 2-worker `SweepEngine.run` under a kill-and-replay
  plan reproducing the clean Pareto front exactly.

``smoke=True`` (the CI chaos smoke step) bounds every range for a
sub-minute run and ASSERTS the bit-identity invariants instead of just
reporting them.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.campaign import CampaignRunner
from repro.distributed import (EvalService, FaultEvent, FaultPlan,
                               ShardedEvaluator, WorkerFault)
from repro.perfmodel import EvalRequest, ModelEvaluator, get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.sweep import SweepEngine

_WORKERS = 2


def _fresh(tier: str = "proxy") -> ModelEvaluator:
    return ModelEvaluator(get_evaluator(tier).models, tier=tier)


def _identical(a, b) -> bool:
    if not (np.array_equal(a.area, b.area) and a.workloads == b.workloads):
        return False
    return all(np.array_equal(a.latency[w], b.latency[w])
               for w in a.workloads)


def _timed(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


class _FlakyDetail:
    """Service backend whose detailed path fails — exercises the ladder."""

    def __init__(self, base):
        self._b = base
        self.workloads = base.workloads

    def evaluate(self, request):
        if request.detail != "objectives":
            raise WorkerFault("detail backend down")
        return self._b.evaluate(request)


def run(smoke: bool = False, workers: int = _WORKERS) -> List[str]:
    lines: List[str] = []
    rng = np.random.default_rng(0)
    batch = SPACE.sample(rng, 1_024 if smoke else 8_192)
    repeats = 3 if smoke else 5

    # ---- chaos-off overhead: full fault machinery, zero events ----
    plain = ShardedEvaluator(_fresh(), workers=workers, validate=False)
    armed = ShardedEvaluator(_fresh(), workers=workers, validate=True,
                             fault_plan=FaultPlan())     # empty plan
    req = EvalRequest(batch, detail="objectives")
    ref = plain.evaluate(req)                            # warm both paths
    armed_rep = armed.evaluate(req)
    t_plain = _timed(lambda: plain.evaluate(req), repeats)
    t_armed = _timed(lambda: armed.evaluate(req), repeats)
    overhead = 100.0 * (t_armed - t_plain) / max(t_plain, 1e-9)
    lines.append(f"faults,chaos_off_overhead_pct,{overhead:.2f}")
    lines.append(f"faults,chaos_off_identical,"
                 f"{int(_identical(armed_rep, ref))}")
    plain.close()
    armed.close()

    # ---- recovery latency vs fault rate (bit-identical throughout) ----
    rounds = 8 if smoke else 24          # cover the plan's dispatch ordinals
    for rate in (0.0, 0.1, 0.3):
        plan = FaultPlan.seeded(17, workers=workers,
                                dispatches=rounds * workers, rate=rate,
                                kinds=("crash", "slow", "corrupt"),
                                delay_s=0.01)
        ev = ShardedEvaluator(_fresh(), workers=workers, retries=8,
                              fault_plan=plan)
        t0 = time.perf_counter()
        ok = True
        for _ in range(rounds):
            ok &= _identical(ev.evaluate(EvalRequest(batch, "objectives")),
                             ref)
        dt = time.perf_counter() - t0
        if smoke:
            assert ok, f"chaos rate={rate} broke bit-identity"
            assert rate == 0.0 or sum(plan.fired.values()) > 0
        lines.append(f"faults,recovery_identical_rate{rate},{int(ok)}")
        lines.append(f"faults,recovery_seconds_rate{rate},{dt:.3f}")
        lines.append(f"faults,recovery_retried_rate{rate},{ev.retried}")
        lines.append(f"faults,recovery_fired_rate{rate},"
                     f"{sum(plan.fired.values())}")
        ev.close()

    # ---- hang -> timeout -> evict -> re-register round trip ----
    ev = ShardedEvaluator(_fresh(), workers=workers,
                          fault_plan=FaultPlan([FaultEvent(0, 0, "hang")]),
                          shard_timeout_s=0.3, speculate=False)
    t0 = time.perf_counter()
    rep = ev.evaluate(EvalRequest(batch, detail="objectives"))
    dt = time.perf_counter() - t0
    ok = _identical(rep, ref)
    if smoke:
        assert ok and ev.timeouts == 1 and ev.registry.reregistrations == 1
    lines.append(f"faults,hang_recovery_identical,{int(ok)}")
    lines.append(f"faults,hang_recovery_seconds,{dt:.3f}")
    lines.append(f"faults,hang_evictions,{ev.registry.evictions}")
    ev.close()

    # ---- degradation-ladder hit rates (zero unhandled exceptions) ----
    svc = EvalService(_fresh())
    warm = SPACE.sample(rng, 64)
    svc.evaluate(EvalRequest(warm, detail="ppa"))        # warm the row cache
    svc.evaluator = _FlakyDetail(_fresh())
    unhandled = 0
    n_req = 16 if smoke else 64
    futs = []
    for i in range(n_req):
        if i % 4 == 0:       # cached rung: rows already in the shared cache
            fut = svc.submit(EvalRequest(warm[i % 64: i % 64 + 8], "stalls"))
        elif i % 4 == 1:     # deadline rung: demoted before dispatch
            fut = svc.submit(EvalRequest(SPACE.sample(rng, 8), "stalls"),
                             deadline_s=0.0)
        else:                # proxy rung: detailed dispatch fails, demote
            fut = svc.submit(EvalRequest(SPACE.sample(rng, 8), "ppa"))
        futs.append(fut)
        svc.tick()
    for fut in futs:
        if fut.exception(timeout=1) is not None:
            unhandled += 1
    tel = svc.telemetry()
    served = tel["coalesced_requests"] + tel["cache_hits"]
    lines.append(f"faults,degrade_requests,{n_req}")
    lines.append(f"faults,degrade_unhandled,{unhandled}")
    for rung in ("deadline", "narrow", "proxy", "cached"):
        lines.append(f"faults,degrade_{rung}_hits,{tel['degraded'][rung]}")
    lines.append(f"faults,degrade_served,{served}")
    if smoke:
        assert unhandled == 0, "degradation ladder leaked an exception"
        assert tel["degraded"]["proxy"] > 0
        assert tel["degraded"]["deadline"] > 0

    # ---- chaos sweep: kill worker 0 mid-sweep, replay, exact merge ----
    eng = SweepEngine(get_evaluator("proxy"), chunk_size=8_192)
    n = (4 if smoke else 16) * 8_192
    t0 = time.perf_counter()
    clean = eng.run(0, n)
    t_clean = time.perf_counter() - t0
    plan = FaultPlan([FaultEvent(0, 1, "crash"),
                      FaultEvent(1, 1, "slow", delay_s=0.01)])
    t0 = time.perf_counter()
    res = eng.run(0, n, workers=2, fault_plan=plan)
    t_chaos = time.perf_counter() - t0
    ok = (np.array_equal(clean.pareto_ids, res.pareto_ids)
          and np.array_equal(clean.topk_ids, res.topk_ids)
          and clean.n_superior == res.n_superior)
    if smoke:
        assert ok, "chaos sweep broke bit-identity"
        assert plan.fired["crash"] == 1
    lines.append(f"faults,sweep_chaos_identical,{int(ok)}")
    lines.append(f"faults,sweep_clean_seconds,{t_clean:.2f}")
    lines.append(f"faults,sweep_chaos_seconds,{t_chaos:.2f}")

    # ---- campaign through the service under seeded chaos ----
    budget = 8 if smoke else 16
    seeds = {"memory_bw": SPACE.sample(np.random.default_rng(1), 2)}
    clean_res = CampaignRunner(EvalService(_fresh()),
                               proxy=get_evaluator("proxy"), seed=0).run(
        budget=budget, seeds={k: v.copy() for k, v in seeds.items()})
    plan = FaultPlan.seeded(11, workers=workers, dispatches=64, rate=0.25,
                            kinds=("crash", "slow", "corrupt"), delay_s=0.01)
    sharded = ShardedEvaluator(_fresh(), workers=workers, retries=8,
                               fault_plan=plan)
    chaos_svc = EvalService(sharded)
    res = CampaignRunner(chaos_svc, proxy=get_evaluator("proxy"),
                         seed=0).run(budget=budget, seeds=seeds)
    ok = ([s.idx.tolist() for s in res.samples]
          == [s.idx.tolist() for s in clean_res.samples]
          and res.phv == clean_res.phv)
    if smoke:
        assert ok, "chaos campaign diverged from the clean run"
        assert res.service_counters["campaign_resubmits"] == 0
    lines.append(f"faults,campaign_chaos_identical,{int(ok)}")
    lines.append(f"faults,campaign_faults_fired,"
                 f"{sum(plan.fired.values())}")
    lines.append(f"faults,campaign_resubmits,"
                 f"{res.service_counters['campaign_resubmits']}")
    sharded.close()
    return lines


if __name__ == "__main__":
    for ln in run(smoke=True):
        print(ln)
