"""Kernel microbenchmarks: wall time of the jnp oracle on CPU (the kernels
themselves are TPU-target; interpret mode is correctness-only, so the CSV
reports oracle timings + kernel-vs-oracle max error)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.ppa_eval.ops import ppa_eval
from repro.kernels.ppa_eval.ref import ppa_eval_ref
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.workload import gpt3_layer_prefill


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args)                                   # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6      # us


def run() -> List[str]:
    rng = np.random.default_rng(0)
    lines = []

    b, s, h, hd = 2, 256, 4, 64
    q, k, v = (jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
               for _ in range(3))
    ref_fn = jax.jit(lambda q, k, v: attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b * h, s, hd),
        k.transpose(0, 2, 1, 3).reshape(b * h, s, hd),
        v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)))
    us = _time(ref_fn, q, k, v)
    out = flash_attention(q, k, v, interpret=True, block_q=128, block_k=128)
    ref = ref_fn(q, k, v).reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    err = float(jnp.abs(out - ref).max())
    lines.append(f"kernels,flash_attention_oracle,{us:.1f},maxerr={err:.2e}")

    t = 128
    r2 = jnp.asarray(rng.standard_normal((b, t, h, hd)) * .5, jnp.float32)
    w2 = jnp.asarray(rng.uniform(.3, .99, (b, t, h, hd)), jnp.float32)
    u2 = jnp.asarray(rng.standard_normal((h, hd)) * .1, jnp.float32)
    fl = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    uf = jnp.broadcast_to(u2[None], (b, h, hd)).reshape(b * h, 1, hd)
    ref_fn = jax.jit(lambda r: rwkv6_scan_ref(fl(r), fl(r), fl(r), fl(w2), uf))
    us = _time(ref_fn, r2)
    y = rwkv6_scan(r2, r2, r2, w2, u2, interpret=True)
    ref = ref_fn(r2).reshape(b, h, t, hd).transpose(0, 2, 1, 3)
    err = float(jnp.abs(y - ref).max())
    lines.append(f"kernels,rwkv6_scan_oracle,{us:.1f},maxerr={err:.2e}")

    d, n = 64, 16
    u3 = jnp.asarray(rng.standard_normal((b, t, d)), jnp.float32)
    dt3 = jnp.asarray(rng.uniform(.001, .1, (b, t, d)), jnp.float32)
    a3 = -jnp.asarray(rng.uniform(.5, 2., (d, n)), jnp.float32)
    B3 = jnp.asarray(rng.standard_normal((b, t, n)), jnp.float32)
    C3 = jnp.asarray(rng.standard_normal((b, t, n)), jnp.float32)
    ref_fn = jax.jit(ssm_scan_ref)
    us = _time(ref_fn, u3, dt3, a3, B3, C3)
    y = ssm_scan(u3, dt3, a3, B3, C3, interpret=True)
    err = float(jnp.abs(y - ref_fn(u3, dt3, a3, B3, C3)).max())
    lines.append(f"kernels,ssm_scan_oracle,{us:.1f},maxerr={err:.2e}")

    wl = gpt3_layer_prefill()
    idx = SPACE.sample(rng, 512)
    t0 = time.time()
    ref = ppa_eval_ref(idx, wl)
    us = (time.time() - t0) * 1e6
    out = ppa_eval(idx, wl, interpret=True)
    err = float(np.abs(out["latency"] - ref[:, 0]).max()
                / np.abs(ref[:, 0]).max())
    lines.append(f"kernels,ppa_eval_512designs,{us:.1f},relerr={err:.2e}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
