"""Paper §5.3 LLMCompass-budget experiment: 20 evaluations on the
high-fidelity tier.  Paper: Lumina is the ONLY method that finds designs
beating the A100 — six of them; every black-box baseline finds zero.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.baselines import METHODS, run_method
from repro.core.loop import LuminaDSE
from repro.perfmodel import make_paper_evaluator
from repro.perfmodel.designspace import SPACE, A100_REFERENCE


def run(budget: int = 20, trials: int = 3) -> List[str]:
    ct, cp, evaluator = make_paper_evaluator("compass")
    rt, rp, _ = make_paper_evaluator("roofline")

    ref = evaluator(SPACE.encode_nearest(A100_REFERENCE)[None, :])[0]
    lines = []
    for name, cls in METHODS.items():
        sups = [run_method(cls, evaluator, budget, ref, seed=t).superior_count
                for t in range(trials)]
        lines.append(f"budget20,{name}_superior_mean,{np.mean(sups):.1f}")
    sups = [LuminaDSE(ct, cp, proxy_models=(rt, rp), seed=t)
            .run(budget=budget).superior_count for t in range(trials)]
    lines.append(f"budget20,LUMINA_superior_mean,{np.mean(sups):.1f}")
    lines.append(f"budget20,LUMINA_superior_min,{min(sups)}")
    lines.append("budget20,paper_claim,LUMINA>=6_baselines=0")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
