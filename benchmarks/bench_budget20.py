"""Paper §5.3 LLMCompass-budget experiment: 20 evaluations on the
high-fidelity (target) tier.  Paper: Lumina is the ONLY method that finds
designs beating the A100 — six of them; every black-box baseline finds zero.

All methods run through the unified Evaluator API (one fused jitted dispatch
per DSE step; the emitted ``LUMINA_dispatches_per_eval`` counter verifies
it).  PHV is reported oracle-normalized against the exhaustive compass-tier
sweep front.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.baselines import METHODS, run_method
from repro.core.loop import LuminaDSE
from repro.perfmodel import get_evaluator
from repro.perfmodel.designspace import SPACE, A100_REFERENCE


def run(budget: int = 20, trials: int = 3) -> List[str]:
    target = get_evaluator("target")
    proxy = get_evaluator("proxy")
    oracle = get_evaluator("oracle", "compass")   # target-tier ground truth

    ref = target.objectives(SPACE.encode_nearest(A100_REFERENCE)[None, :])[0]
    lines = []
    for name, cls in METHODS.items():
        sups = [run_method(cls, target, budget, ref, seed=t).superior_count
                for t in range(trials)]
        lines.append(f"budget20,{name}_superior_mean,{np.mean(sups):.1f}")
    sups, phvs, disp = [], [], []
    for t in range(trials):
        d0 = target.dispatches
        res = LuminaDSE(target, proxy=proxy, seed=t).run(budget=budget)
        disp.append((target.dispatches - d0) / budget)
        sups.append(res.superior_count)
        phvs.append(res.phv)
    lines.append(f"budget20,LUMINA_superior_mean,{np.mean(sups):.1f}")
    lines.append(f"budget20,LUMINA_superior_min,{min(sups)}")
    lines.append(f"budget20,LUMINA_phv_frac_of_oracle,"
                 f"{oracle.normalized_phv(np.mean(phvs), ref):.4f}")
    lines.append(f"budget20,LUMINA_dispatches_per_eval,{np.mean(disp):.2f}")
    lines.append("budget20,paper_claim,LUMINA>=6_baselines=0")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
